"""Per-kernel validation: shape/dtype sweeps asserting allclose against the
pure-jnp oracles (interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.crossmatch import ops as cm_ops
from repro.kernels.crossmatch.ref import crossmatch_ref
from repro.kernels.grouped_matmul.ops import grouped_matmul, hybrid_grouped_matmul
from repro.kernels.grouped_matmul.ref import grouped_matmul_ref, row_groups
from repro.kernels.paged_attention.ops import dense_to_pages, paged_attention


def _unit(n, seed):
    v = np.random.default_rng(seed).normal(size=(n, 3))
    return (v / np.linalg.norm(v, axis=1, keepdims=True)).astype(np.float32)


# ------------------------------------------------------------------ crossmatch
class TestCrossmatch:
    @pytest.mark.parametrize("n,m", [(256, 128), (700, 300), (1024, 1), (33, 513)])
    @pytest.mark.parametrize("radius", [0.01, 0.1])
    def test_matches_ref(self, n, m, radius):
        bkt, prb = _unit(n, 1), _unit(m, 2)
        thr = float(np.cos(radius))
        ri, rd, rc = cm_ops.crossmatch(bkt, prb, thr, use_pallas=False)
        pi, pd, pc = cm_ops.crossmatch(bkt, prb, thr, use_pallas=True, bm=128, bn=256)
        np.testing.assert_array_equal(np.asarray(rc), np.asarray(pc))
        np.testing.assert_allclose(np.asarray(rd), np.asarray(pd), rtol=1e-6)
        # argmax may tie; verify the dot of the chosen index is the max
        dots = np.asarray(prb) @ np.asarray(bkt).T
        np.testing.assert_allclose(
            dots[np.arange(m), np.asarray(pi)], dots.max(axis=1), rtol=1e-5
        )

    @pytest.mark.parametrize("bm,bn", [(128, 256), (128, 512), (256, 128)])
    def test_block_shape_sweep(self, bm, bn):
        bkt, prb = _unit(500, 3), _unit(200, 4)
        thr = float(np.cos(0.05))
        ri, rd, rc = cm_ops.crossmatch(bkt, prb, thr, use_pallas=False)
        pi, pd, pc = cm_ops.crossmatch(bkt, prb, thr, use_pallas=True, bm=bm, bn=bn)
        np.testing.assert_array_equal(np.asarray(rc), np.asarray(pc))
        np.testing.assert_allclose(np.asarray(rd), np.asarray(pd), rtol=1e-6)

    def test_self_match(self):
        """Every point matches itself at any positive radius."""
        pts = _unit(300, 5)
        _, d, c = cm_ops.crossmatch(pts, pts, float(np.cos(0.01)), use_pallas=True)
        assert (np.asarray(c) >= 1).all()
        np.testing.assert_allclose(np.asarray(d), 1.0, atol=1e-5)

    def test_banded_near_diagonal(self):
        """With SFC-sorted identical sets, a moderate band keeps all matches."""
        from repro.core.sfc import htm_id

        pts = _unit(1024, 6)
        order = np.argsort(htm_id(pts, level=8), kind="stable")
        pts = pts[order]
        thr = float(np.cos(0.01))
        fi, fd, fc = cm_ops.crossmatch(pts, pts, thr, use_pallas=True, bm=128, bn=128)
        bi, bd, bc = cm_ops.crossmatch(
            pts, pts, thr, use_pallas=True, bm=128, bn=128, band=0
        )
        # band=0 keeps only the diagonal tile: self-match must survive
        np.testing.assert_allclose(np.asarray(bd), 1.0, atol=1e-5)
        assert (np.asarray(bc) >= 1).all()
        assert (np.asarray(bc) <= np.asarray(fc)).all()

    @given(st.integers(1, 400), st.integers(1, 400))
    @settings(max_examples=10, deadline=None)
    def test_property_any_shape(self, n, m):
        bkt, prb = _unit(n, n), _unit(m, m + 1)
        thr = float(np.cos(0.05))
        ri, rd, rc = cm_ops.crossmatch(bkt, prb, thr, use_pallas=False)
        pi, pd, pc = cm_ops.crossmatch(bkt, prb, thr, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(rc), np.asarray(pc))

    @pytest.mark.parametrize("radius", [1.7, 2.0, 3.0])
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_padded_rows_not_counted_at_large_radius(self, radius, use_pallas):
        """Regression: cos_thr <= 0 used to count every zero-padded bucket
        row (dot 0 >= cos_thr) in n_cand.  The marker-column sentinel pins
        padded-row dots at -2, below any threshold."""
        bkt, prb = _unit(700, 7), _unit(300, 8)  # 700 % bn != 0 forces padding
        thr = float(np.cos(radius))
        assert thr <= 0.0
        ri, rd, rc = crossmatch_ref(jnp.asarray(bkt), jnp.asarray(prb), thr)
        _, d, c = cm_ops.crossmatch(bkt, prb, thr, use_pallas=use_pallas, bm=128, bn=256)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))
        np.testing.assert_allclose(np.asarray(d), np.asarray(rd), rtol=1e-6)

    def test_shape_bucketing_bounds_compiles(self):
        """Sweeping probe counts must reuse O(log M) compiled shapes."""
        bkt = _unit(500, 11)
        thr = float(np.cos(0.05))
        before = cm_ops.jit_cache_size()
        for m in (3, 5, 6, 7, 9, 13, 40, 41, 47, 100, 117):
            cm_ops.crossmatch(bkt, _unit(m, m), thr, use_pallas=False)
        grown = cm_ops.jit_cache_size() - before
        # 11 distinct sizes -> pow2 buckets {8, 16, 64, 128} -> <= 4 shapes
        assert 0 <= grown <= 4, grown


class TestCrossmatchFused:
    def _segments(self, sizes_b, sizes_p, seed=0):
        bkts = [_unit(n, seed + 10 + i) for i, n in enumerate(sizes_b)]
        prbs = [_unit(m, seed + 50 + i) for i, m in enumerate(sizes_p)]
        B, P = np.concatenate(bkts), np.concatenate(prbs)
        bseg = np.repeat(np.arange(len(sizes_b)), sizes_b)
        pseg = np.repeat(np.arange(len(sizes_p)), sizes_p)
        return bkts, prbs, B, P, bseg, pseg

    @pytest.mark.parametrize("use_pallas", [False, True])
    @pytest.mark.parametrize("radius", [0.05, 0.5])
    def test_matches_per_segment_oracle(self, use_pallas, radius):
        sizes_b, sizes_p = [100, 100, 57], [40, 1, 130]
        bkts, prbs, B, P, bseg, pseg = self._segments(sizes_b, sizes_p)
        thr = float(np.cos(radius))
        fi, fd, fc = cm_ops.crossmatch_fused(
            B, P, bseg, pseg, thr, use_pallas=use_pallas, bm=128, bn=128
        )
        fi, fd, fc = map(np.asarray, (fi, fd, fc))
        off_b = np.cumsum([0] + sizes_b)
        off_p = np.cumsum([0] + sizes_p)
        for s in range(len(sizes_b)):
            ri, rd, rc = map(
                np.asarray,
                crossmatch_ref(jnp.asarray(bkts[s]), jnp.asarray(prbs[s]), thr),
            )
            sl = slice(off_p[s], off_p[s + 1])
            np.testing.assert_array_equal(fc[sl], rc)
            np.testing.assert_allclose(fd[sl], rd, rtol=1e-6)
            dots = prbs[s] @ bkts[s].T
            chosen = dots[np.arange(sizes_p[s]), fi[sl] - off_b[s]]
            np.testing.assert_allclose(chosen, dots.max(axis=1), rtol=1e-5)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_probe_segment_without_bucket_rows(self, use_pallas):
        """A probe whose segment has no bucket rows matches nothing."""
        B = _unit(64, 1)
        P = _unit(10, 2)
        bseg = np.zeros(64, np.int32)
        pseg = np.full(10, 3, np.int32)  # segment 3 has no bucket rows
        _, d, c = cm_ops.crossmatch_fused(
            B, P, bseg, pseg, float(np.cos(3.0)), use_pallas=use_pallas,
            bm=128, bn=128,
        )
        assert (np.asarray(c) == 0).all()
        assert (np.asarray(d) <= -1.5).all()  # masked sentinel, never a match


# ------------------------------------------------------------------ grouped matmul
class TestGroupedMatmul:
    @pytest.mark.parametrize(
        "sizes,d,f",
        [
            ([128, 256, 128, 512], 256, 192),
            ([128, 128], 512, 512),
            ([384, 128, 128, 128, 256], 128, 64),
        ],
    )
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, sizes, d, f, dtype):
        rng = np.random.default_rng(0)
        sizes = jnp.array(sizes)
        T, G = int(sizes.sum()), len(sizes)
        x = jnp.asarray(rng.normal(size=(T, d)), dtype)
        w = jnp.asarray(rng.normal(size=(G, d, f)) * 0.1, dtype)
        ref = grouped_matmul_ref(x.astype(jnp.float32), sizes, w.astype(jnp.float32))
        out = grouped_matmul(x, sizes, w, bt=128, bf=64, bk=128, use_pallas=True)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref), rtol=tol, atol=tol
        )

    def test_block_sweep(self):
        rng = np.random.default_rng(1)
        sizes = jnp.array([256, 256, 512])
        x = jnp.asarray(rng.normal(size=(1024, 384)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(3, 384, 256)) * 0.1, jnp.float32)
        ref = grouped_matmul_ref(x, sizes, w)
        for bt, bf, bk in [(128, 128, 128), (256, 256, 384), (128, 64, 192)]:
            out = grouped_matmul(x, sizes, w, bt=bt, bf=bf, bk=bk, use_pallas=True)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5
            )

    def test_row_groups(self):
        g = row_groups(jnp.array([2, 3, 1]), 6)
        np.testing.assert_array_equal(np.asarray(g), [0, 0, 1, 1, 1, 2])

    def test_hybrid_paths_agree(self):
        """Indexed (gather) and scan (kernel) paths compute the same y."""
        rng = np.random.default_rng(2)
        sizes = jnp.array([128, 128, 256])
        x = jnp.asarray(rng.normal(size=(512, 128)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(3, 128, 64)) * 0.1, jnp.float32)
        ref = grouped_matmul_ref(x, sizes, w)
        hyb = hybrid_grouped_matmul(x, sizes, w, threshold_rows=129, bt=128, bf=64, bk=128)
        np.testing.assert_allclose(np.asarray(hyb), np.asarray(ref), rtol=1e-4)


# ------------------------------------------------------------------ paged attention
class TestPagedAttention:
    @pytest.mark.parametrize("h,kv", [(8, 8), (8, 4), (8, 1), (16, 2)])
    @pytest.mark.parametrize("page,pages", [(16, 4), (32, 2), (8, 16)])
    def test_matches_ref(self, h, kv, page, pages):
        rng = np.random.default_rng(0)
        B, D = 3, 32
        S = page * pages
        q = jnp.asarray(rng.normal(size=(B, h, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, kv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, kv, D)), jnp.float32)
        kp, vp, pt = dense_to_pages(k, v, page)
        lens = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
        ref = paged_attention(q, kp, vp, pt, lens, use_pallas=False)
        out = paged_attention(q, kp, vp, pt, lens, use_pallas=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(1)
        B, H, KV, D, page, P = 2, 8, 4, 64, 16, 4
        S = page * P
        q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.bfloat16)
        kp, vp, pt = dense_to_pages(k, v, page)
        lens = jnp.array([S, S // 2], jnp.int32)
        ref = paged_attention(q, kp, vp, pt, lens, use_pallas=False)
        out = paged_attention(q, kp, vp, pt, lens, use_pallas=True)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=5e-2, atol=5e-2,
        )

    def test_shared_pages_across_sequences(self):
        """Two sequences pointing at the SAME pages (prefix sharing — the
        bucket-contention case) attend identically."""
        rng = np.random.default_rng(2)
        B, H, KV, D, page, P = 2, 4, 4, 16, 8, 4
        q1 = jnp.asarray(rng.normal(size=(1, H, D)), jnp.float32)
        q = jnp.concatenate([q1, q1], axis=0)
        kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
        pt = jnp.tile(jnp.arange(P, dtype=jnp.int32)[None], (B, 1))
        lens = jnp.array([page * P, page * P], jnp.int32)
        out = paged_attention(q, kp, vp, pt, lens, use_pallas=True)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out[1]), rtol=1e-6)

    def test_length_masking(self):
        """Slots past seq_len must not contribute: perturbing them is a no-op."""
        rng = np.random.default_rng(3)
        B, H, KV, D, page, P = 1, 4, 2, 16, 8, 4
        S = page * P
        q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
        kp, vp, pt = dense_to_pages(k, v, page)
        lens = jnp.array([10], jnp.int32)
        out1 = paged_attention(q, kp, vp, pt, lens, use_pallas=True)
        kp2 = kp.at[2:].set(99.0)
        vp2 = vp.at[2:].set(-99.0)
        out2 = paged_attention(q, kp2, vp2, pt, lens, use_pallas=True)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)

"""Unit + property tests for the LifeRaft core: buckets, workload queues,
metrics (Eq. 1/2), cache, schedulers, hybrid planner, adaptive alpha."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BucketCache,
    CostModel,
    HybridCostModel,
    HybridPlanner,
    LifeRaftScheduler,
    OrderedScheduler,
    Partitioner,
    Query,
    RoundRobinScheduler,
    TradeoffPoint,
    TradeoffTable,
    AlphaController,
    WorkloadManager,
    aged_workload_throughput,
    workload_throughput,
    run_policy,
)
from repro.core.simulate import simulate_batched, simulate_noshare


# ---------------------------------------------------------------- partitioner
class TestPartitioner:
    def test_equal_counts(self):
        keys = np.random.default_rng(0).integers(0, 2**32, 10_000).astype(np.uint64)
        p = Partitioner(keys, objects_per_bucket=1000)
        counts = [s.count for s in p.specs]
        assert sum(counts) == 10_000
        assert all(c == 1000 for c in counts[:-1])

    def test_bucket_of_keys_consistent(self):
        keys = np.random.default_rng(1).integers(0, 2**20, 5_000).astype(np.uint64)
        p = Partitioner(keys, objects_per_bucket=500)
        b = p.bucket_of_keys(keys)
        for bid in range(p.n_buckets):
            spec = p.specs[bid]
            sel = keys[b == bid]
            assert (sel >= spec.key_lo).all()

    def test_range_overlap(self):
        keys = np.arange(1000, dtype=np.uint64) * 10
        p = Partitioner(keys, objects_per_bucket=100)
        bs = p.buckets_for_range(0, int(keys[-1]))
        np.testing.assert_array_equal(bs, np.arange(p.n_buckets))

    def test_object_slice_partition(self):
        keys = np.random.default_rng(2).integers(0, 2**16, 1_000).astype(np.uint64)
        p = Partitioner(keys, objects_per_bucket=100)
        all_idx = np.concatenate([p.object_slice(b) for b in range(p.n_buckets)])
        assert sorted(all_idx.tolist()) == list(range(1000))


# ---------------------------------------------------------------- metrics
class TestMetrics:
    def test_eq1_formula(self):
        cm = CostModel(T_b=1.2, T_m=0.13e-3)
        w = 500
        assert workload_throughput(w, False, cm) == pytest.approx(
            w / (1.2 + 0.13e-3 * w)
        )
        assert workload_throughput(w, True, cm) == pytest.approx(w / (0.13e-3 * w))

    def test_cached_bucket_preferred(self):
        cm = CostModel()
        assert workload_throughput(100, True, cm) > workload_throughput(100, False, cm)

    def test_zero_queue(self):
        assert workload_throughput(0, False, CostModel()) == 0.0

    @given(st.floats(0.0, 1.0), st.integers(1, 10_000), st.integers(1, 10_000))
    @settings(max_examples=100, deadline=None)
    def test_eq2_blend_bounds(self, alpha, w1, w2):
        """U_a interpolates: alpha=0 ranks by U_t only, alpha=1 by age only."""
        cm = CostModel()
        sizes = {1: w1, 2: w2}
        ages = {1: 50.0, 2: 500.0}
        cached = {1: False, 2: False}
        ua = aged_workload_throughput(sizes, ages, cached, cm, alpha)
        if alpha == 0.0:
            ut1 = workload_throughput(w1, False, cm)
            ut2 = workload_throughput(w2, False, cm)
            assert (ua[1] >= ua[2]) == (ut1 >= ut2)
        if alpha == 1.0:
            assert ua[2] > ua[1]  # strictly older wins

    def test_alpha_validation(self):
        with pytest.raises(ValueError):
            aged_workload_throughput({}, {}, {}, CostModel(), 1.5)

    def test_monotone_in_queue_size_cold(self):
        cm = CostModel()
        us = [workload_throughput(w, False, cm) for w in (1, 10, 100, 1000)]
        assert us == sorted(us)


# ---------------------------------------------------------------- cache
class TestBucketCache:
    def test_lru_eviction_order(self):
        c = BucketCache(2)
        c.access(1)
        c.access(2)
        c.access(1)  # 2 is now LRU
        ev = c.access(3)
        assert ev == [2]
        assert c.contains(1) and c.contains(3)

    def test_hit_rate(self):
        c = BucketCache(4)
        for b in [1, 2, 1, 1, 3]:
            c.access(b)
        assert c.stats.hits == 2 and c.stats.misses == 3
        assert c.stats.hit_rate == pytest.approx(0.4)

    def test_pinned_not_evicted(self):
        c = BucketCache(1)
        c.access(1)
        c.pin(1)
        c.access(2)
        assert c.contains(1)
        c.unpin(1)
        c.access(3)
        assert not c.contains(1) or not c.contains(2)

    @given(st.lists(st.integers(0, 20), min_size=1, max_size=300), st.integers(1, 10))
    @settings(max_examples=60, deadline=None)
    def test_capacity_invariant(self, accesses, cap):
        c = BucketCache(cap)
        for b in accesses:
            c.access(b)
        assert len(c) <= cap
        assert c.stats.accesses == len(accesses)


# ---------------------------------------------------------------- workload
def _mk_query(qid, t, buckets_per_obj, n_obj=3):
    # keys equal bucket ids when bucket_of_range is identity-range below
    lo = np.array([b for b in buckets_per_obj[:n_obj]], dtype=np.uint64)
    return Query(qid, t, lo, lo)


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


class TestWorkloadManager:
    def test_decomposition_and_completion(self):
        wm = WorkloadManager(_identity_range)
        q = _mk_query(0, 0.0, [1, 1, 2])
        units = wm.submit(q)
        assert {u.bucket_id for u in units} == {1, 2}
        assert wm.queue(1).size == 2 and wm.queue(2).size == 1
        assert wm.complete_bucket(1, 1.0) == []  # still waiting on 2
        assert wm.complete_bucket(2, 2.0) == [0]
        assert wm.response_times()[0] == pytest.approx(2.0)

    def test_interleaving(self):
        wm = WorkloadManager(_identity_range)
        wm.submit(_mk_query(0, 0.0, [5, 5, 5]))
        wm.submit(_mk_query(1, 1.0, [5, 6, 6]))
        assert wm.queue(5).size == 4  # both queries share bucket 5's queue
        assert len(wm.queue(5)) == 2  # as two work units

    def test_ages(self):
        wm = WorkloadManager(_identity_range)
        wm.submit(_mk_query(0, 0.0, [1, 1, 1]))
        wm.submit(_mk_query(1, 5.0, [1, 1, 1]))
        ages = wm.ages_ms(10.0)
        assert ages[1] == pytest.approx(10_000.0)  # oldest request dominates


# ---------------------------------------------------------------- schedulers
class TestSchedulers:
    def _setup(self):
        wm = WorkloadManager(_identity_range)
        wm.submit(_mk_query(0, 0.0, [1, 1, 1]))  # bucket 1: 3 objects, old
        wm.submit(_mk_query(1, 9.0, [2] * 3, n_obj=3))
        wm.queues[2].units[0].object_idx = np.arange(500)  # bucket 2: huge, new
        wm.queues[2]._size = 500
        return wm, BucketCache(4)

    def test_greedy_picks_contention(self):
        wm, cache = self._setup()
        s = LifeRaftScheduler(CostModel(), alpha=0.0)
        assert s.select(wm, cache, 10.0).bucket_id == 2

    def test_aged_picks_oldest(self):
        wm, cache = self._setup()
        s = LifeRaftScheduler(CostModel(), alpha=1.0)
        assert s.select(wm, cache, 10.0).bucket_id == 1

    def test_ordered_equals_alpha1(self):
        wm, cache = self._setup()
        a = OrderedScheduler(CostModel()).select(wm, cache, 10.0)
        b = LifeRaftScheduler(CostModel(), alpha=1.0).select(wm, cache, 10.0)
        assert a.bucket_id == b.bucket_id

    def test_cache_residency_bias(self):
        """Equal queues: the cached bucket must win under alpha=0."""
        wm = WorkloadManager(_identity_range)
        wm.submit(_mk_query(0, 0.0, [1, 1, 1]))
        wm.submit(_mk_query(1, 0.0, [2, 2, 2]))
        cache = BucketCache(4)
        cache.access(2)
        s = LifeRaftScheduler(CostModel(), alpha=0.0)
        assert s.select(wm, cache, 1.0).bucket_id == 2

    def test_rr_cycles_in_id_order(self):
        wm = WorkloadManager(_identity_range)
        for qid, b in enumerate([3, 1, 7]):
            wm.submit(_mk_query(qid, float(qid), [b] * 3))
        rr = RoundRobinScheduler(CostModel())
        cache = BucketCache(4)
        order = []
        for _ in range(3):
            d = rr.select(wm, cache, 0.0)
            order.append(d.bucket_id)
            wm.complete_bucket(d.bucket_id, 0.0)
        assert order == [1, 3, 7]

    def test_empty_returns_none(self):
        wm = WorkloadManager(_identity_range)
        assert LifeRaftScheduler(CostModel()).select(wm, BucketCache(2), 0.0) is None


# ---------------------------------------------------------------- hybrid
class TestHybrid:
    def test_break_even_matches_paper(self):
        """Paper Fig. 2: break-even ~3% of a 10k-object bucket."""
        h = HybridCostModel(T_b=1.2, T_m=0.13e-3, T_probe=4.13e-3)
        assert h.break_even_queue() == pytest.approx(300, rel=0.01)

    def test_planner_small_queue_indexed(self):
        h = HybridCostModel()
        p = HybridPlanner(h, objects_per_bucket=10_000)
        assert p.plan(10, in_cache=False).strategy == "indexed"
        assert p.plan(5_000, in_cache=False).strategy == "scan"

    def test_cached_bucket_always_scans(self):
        p = HybridPlanner(HybridCostModel(), objects_per_bucket=10_000)
        assert p.plan(2, in_cache=True).strategy == "scan"

    def test_fixed_threshold(self):
        p = HybridPlanner(
            HybridCostModel(), objects_per_bucket=10_000, threshold_frac=0.03
        )
        assert p.plan(299, False).strategy == "indexed"
        assert p.plan(301, False).strategy == "scan"


# ---------------------------------------------------------------- adaptive
class TestAdaptive:
    def _table(self):
        t = TradeoffTable()
        t.add(0.1, [TradeoffPoint(0.0, 1.0, 10.0), TradeoffPoint(1.0, 0.93, 4.6)])
        t.add(0.5, [TradeoffPoint(0.0, 1.0, 8.0), TradeoffPoint(0.25, 0.8, 6.4)])
        return t

    def test_select_alpha_low_saturation(self):
        # 7% throughput loss for 54% response gain is within 20% tolerance.
        assert self._table().select_alpha(0.1, tolerance=0.2) == 1.0

    def test_select_alpha_high_saturation(self):
        assert self._table().select_alpha(0.5, tolerance=0.1) == 0.0

    def test_controller_moves_incrementally(self):
        ctl = AlphaController(self._table(), tolerance=0.2, initial_alpha=0.0,
                              max_step=0.1, halflife_s=1.0)
        # Slow arrivals -> low saturation -> alpha drifts up, capped per step.
        a_prev = 0.0
        for t in np.arange(0, 100, 10.0):
            a = ctl.update_on_arrival(float(t))
            assert a - a_prev <= 0.1 + 1e-9
            a_prev = a
        assert a_prev > 0.5


# ---------------------------------------------------------------- simulator
class TestSimulator:
    def _trace(self, n=50, seed=0, hot=4, buckets=30, gap=0.2):
        rng = np.random.default_rng(seed)
        qs = []
        t = 0.0
        for qid in range(n):
            t += rng.exponential(gap)
            if rng.random() < 0.7:
                b = rng.integers(0, hot)
            else:
                b = rng.integers(hot, buckets)
            ks = np.full(rng.integers(2, 20), b, dtype=np.uint64)
            qs.append(Query(qid, t, ks, ks))
        return qs

    def test_all_queries_complete(self):
        qs = self._trace()
        for pol, a in [("noshare", 0), ("rr", 0), ("liferaft", 0.0), ("liferaft", 0.7)]:
            r = run_policy(pol, qs, _identity_range, CostModel(), alpha=a)
            assert r.n_queries == len(qs), pol

    def test_sharing_beats_noshare(self):
        # Paper-like cache pressure: many more buckets than cache slots.
        qs = self._trace(n=300, seed=1, hot=12, buckets=400, gap=0.05)
        greedy = run_policy(
            "liferaft", qs, _identity_range, CostModel(), alpha=0.0, cache_capacity=8
        )
        noshare = run_policy(
            "noshare", qs, _identity_range, CostModel(), cache_capacity=8
        )
        assert greedy.query_throughput > 1.3 * noshare.query_throughput
        assert greedy.mean_response < noshare.mean_response

    def test_greedy_highest_throughput(self):
        # Saturated + cache-pressured, as in the paper's Fig. 7 regime.
        qs = self._trace(n=300, seed=2, hot=12, buckets=400, gap=0.05)
        rs = {
            a: run_policy(
                "liferaft", qs, _identity_range, CostModel(), alpha=a,
                cache_capacity=8,
            )
            for a in (0.0, 1.0)
        }
        assert rs[0.0].query_throughput >= rs[1.0].query_throughput

    def test_cache_hit_rate_higher_for_greedy(self):
        """Paper §6: 40% (alpha=0) vs 7% (alpha=1) serviced from cache."""
        qs = self._trace(n=300, seed=3, hot=3, buckets=60)
        g = run_policy("liferaft", qs, _identity_range, CostModel(), alpha=0.0,
                       cache_capacity=5)
        o = run_policy("liferaft", qs, _identity_range, CostModel(), alpha=1.0,
                       cache_capacity=5)
        assert g.cache_hit_rate > o.cache_hit_rate

    def test_makespan_conservation(self):
        """Busy time can never exceed makespan; work conserves."""
        qs = self._trace(n=100, seed=4)
        r = run_policy("liferaft", qs, _identity_range, CostModel(), alpha=0.3)
        assert r.busy_time <= r.makespan + 1e-6

    @given(st.floats(0.0, 1.0))
    @settings(max_examples=20, deadline=None)
    def test_any_alpha_completes(self, alpha):
        qs = self._trace(n=40, seed=5)
        r = run_policy("liferaft", qs, _identity_range, CostModel(), alpha=alpha)
        assert r.n_queries == 40

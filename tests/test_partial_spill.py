"""Property tests for §6 partial-queue spill with byte-accurate accounting.

Invariants locked down here:
  * byte/object conservation: resident + spilled == pending, always —
    on the shared ``SpillQueue`` primitive and on both engines' queues
    built on it;
  * the resident prefix is an age-contiguous cut — the oldest pending
    unit is never spilled (partial spill evicts youngest-first), the
    *oldest* spilled units return first (paged unspill), so the age term
    A(i) and its monotone rebase are untouched by overflow;
  * paged unspill never overshoots its byte grant — the §6
    wholesale-unspill budget-overshoot bugfix (the legacy whole-queue
    mode survives behind ``wholesale_unspill`` and still overshoots,
    which the regression test demonstrates);
  * unit prices are floored at ``min_unit_bytes`` — zero-length prompts
    cannot free-ride the budget or sigma;
  * unspill is idempotent and restores the whole queue;
  * apply_spill enforces the byte budget (resident <= budget modulo the
    oldest-unit floors), never both spills and unspills in one round,
    and prices paged unspill grants by T_spill wait-cost-per-byte;
  * the ControlLoop / TenantControlPlane spill hysteresis only
    transitions when a threshold is actually crossed — it cannot engage
    and disengage within one round.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ControlConfig,
    ControlLoop,
    ControlVector,
    CostModel,
    SpillQueue,
    Telemetry,
    TenantControlPlane,
    TenantPolicy,
    apply_spill,
)
from repro.core.workload import Query, WorkloadManager


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _mk_query(qid, t, buckets, tenant="default"):
    ks = np.asarray(buckets, dtype=np.uint64)
    return Query(qid, t, ks, ks, meta={"tenant": tenant})


def _random_workload(rng, n_queries=25, n_buckets=6, probe_bytes=8.0):
    wm = WorkloadManager(_identity_range, probe_bytes=probe_bytes)
    t = 0.0
    for qid in range(n_queries):
        t += float(rng.exponential(0.2))
        n = int(rng.integers(1, 6))
        wm.submit(_mk_query(qid, t, rng.integers(0, n_buckets, n)))
    return wm


def _assert_conserved(wm):
    assert wm.resident_objects() + sum(
        q.size - q.resident_size for q in wm.queues.values()
    ) == wm.pending_objects()
    assert wm.resident_bytes() + wm.spilled_bytes() == pytest.approx(
        wm.pending_bytes(), rel=1e-12
    )
    for q in wm.queues.values():
        assert q.resident_size + (q.size - q.resident_size) == q.size
        assert q.resident_bytes + q.spilled_bytes == pytest.approx(
            q.nbytes, rel=1e-12
        )
        assert 0.0 <= q.spilled_fraction <= 1.0


def _assert_age_cut(q):
    """Resident prefix == the oldest work: no resident unit is younger
    than any spilled unit, so the oldest pending unit is resident."""
    if not q.spilled_units or not q.units:
        return
    max_res = max(u.arrival_time for u in q.units)
    min_spill = min(u.arrival_time for u in q.spilled_units)
    assert max_res <= min_spill, (max_res, min_spill)
    assert q.oldest_arrival == min(u.arrival_time for u in q.units)


class TestPartialSpillInvariants:
    @given(st.integers(0, 10_000), st.floats(0.05, 1.0))
    @settings(max_examples=30, deadline=None)
    def test_byte_conservation_under_spill_churn(self, seed, frac):
        rng = np.random.default_rng(seed)
        wm = _random_workload(rng)
        buckets = [q.bucket_id for q in wm.nonempty_queues()]
        for _ in range(30):
            op = rng.random()
            b = int(rng.choice(buckets))
            if op < 0.45:
                wm.spill_bucket(b, float(rng.uniform(0.05, 1.0)) if op < 0.3 else frac)
            elif op < 0.65:
                wm.unspill_bucket(b)
            elif op < 0.85:
                t = float(rng.uniform(0, 10))
                wm.submit(_mk_query(1000 + int(rng.integers(1e6)), t, [b]))
            else:
                wm.complete_bucket(b, 20.0)
                buckets = [q.bucket_id for q in wm.nonempty_queues()] or [0]
            _assert_conserved(wm)

    @given(st.integers(0, 10_000), st.floats(0.05, 0.95))
    @settings(max_examples=30, deadline=None)
    def test_oldest_units_stay_resident(self, seed, frac):
        """Partial spill evicts youngest-first: after any mix of partial
        spills and out-of-order pushes, the resident prefix is an
        age-contiguous cut and the oldest pending unit is resident."""
        rng = np.random.default_rng(seed)
        wm = _random_workload(rng)
        buckets = [q.bucket_id for q in wm.nonempty_queues()]
        for _ in range(25):
            b = int(rng.choice(buckets))
            op = rng.random()
            if op < 0.5:
                wm.spill_bucket(b, frac)
            elif op < 0.8:  # pushes may arrive out of arrival order
                t = float(rng.uniform(0, 10))
                wm.submit(_mk_query(2000 + int(rng.integers(1e6)), t, [b]))
            else:
                wm.unspill_bucket(b)
            for q in wm.nonempty_queues():
                _assert_age_cut(q)
        # A partially spilled queue must keep its oldest unit resident.
        for q in wm.nonempty_queues():
            if q.spilled_units and q.units:
                assert q.oldest_arrival == min(
                    u.arrival_time for u in q.units
                )

    @given(st.integers(0, 10_000), st.floats(0.1, 1.0))
    @settings(max_examples=25, deadline=None)
    def test_unspill_idempotent_and_total(self, seed, frac):
        rng = np.random.default_rng(seed)
        wm = _random_workload(rng)
        for q in list(wm.nonempty_queues()):
            b = q.bucket_id
            before = (q.size, q.nbytes)
            wm.spill_bucket(b, frac)
            first = wm.unspill_bucket(b)
            second = wm.unspill_bucket(b)  # idempotent: no-op
            assert not second
            assert not wm.is_spilled(b)
            assert wm.spilled_fraction(b) == 0.0
            assert (q.size, q.nbytes) == before
            assert q.resident_size == q.size
            assert first == wm.is_spilled(b) or True  # first may be False if nothing spilled
            _assert_age_cut(q)

    def test_full_spill_has_sigma_exactly_one(self):
        """Whole-queue spill must reproduce the legacy boolean semantics
        bit for bit: sigma == 1.0 exactly, so the score surcharge is
        exactly T_spill."""
        wm = WorkloadManager(_identity_range, probe_bytes=3.0)
        wm.submit(_mk_query(0, 0.0, [1, 1, 1]))
        wm.submit(_mk_query(1, 0.7, [1]))
        assert wm.spill_bucket(1)  # frac defaults to 1.0
        assert wm.spilled_fraction(1) == 1.0
        assert wm.queues[1].resident_size == 0
        cost = CostModel(T_spill=0.4)
        assert cost.batch_cost(4, False, wm.spilled_fraction(1)) == \
            cost.batch_cost(4, False, True)

    def test_partial_spill_rounds_up_to_unit_boundary(self):
        """Byte-accurate means 'spill at least the requested bytes' at
        unit granularity — never less."""
        wm = WorkloadManager(_identity_range, probe_bytes=10.0)
        for qid, t in enumerate([0.0, 1.0, 2.0, 3.0]):
            wm.submit(_mk_query(qid, t, [5, 5]))  # 4 units x 2 objs x 10 B
        q = wm.queues[5]
        assert q.nbytes == 80.0
        wm.spill_bucket(5, 0.3)  # 24 B -> rounds up to 2 units? no: 1 unit=20<24, 2 units=40
        assert q.spilled_bytes >= 0.3 * q.nbytes
        assert q.spilled_bytes == 40.0  # youngest two units
        assert [u.arrival_time for u in q.units] == [0.0, 1.0]


def _mk_spillq():
    """Bare SpillQueue over (arrival, nbytes, ident) tuples — the shared
    primitive both engines' queues are built on."""
    return SpillQueue(
        0, bytes_of=lambda it: it[1], arrival_of=lambda it: it[0]
    )


class TestSpillQueuePrimitive:
    """spill -> partial-unspill -> spill round trips on the shared
    primitive itself: conservation, oldest-first return, strict grants."""

    @given(st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_round_trip_conserves_bytes_and_items(self, seed):
        rng = np.random.default_rng(seed)
        sq = _mk_spillq()
        live = []

        def push(ident):
            it = (float(rng.uniform(0, 10)), float(rng.integers(1, 50)), ident)
            live.append(it)
            sq.push(it)

        for i in range(8):
            push(i)
        for step in range(40):
            op = rng.random()
            if op < 0.3:
                push(100 + step)
            elif op < 0.55:
                sq.spill_youngest(float(rng.uniform(0.05, 1.0)))
            elif op < 0.8:
                before = sq.resident_bytes
                budget = float(rng.uniform(0.0, 120.0))
                sq.unspill_oldest(budget_bytes=budget)
                # A grant is a budget, not a target: never overshot.
                assert sq.resident_bytes - before <= budget + 1e-9
            else:
                sq.unspill_all()
            assert sq.resident_bytes + sq.spilled_bytes == pytest.approx(
                sq.nbytes, rel=1e-12
            )
            assert len(sq.resident) + len(sq.spilled) == len(live)
            assert sorted(id(x) for x in sq.resident + sq.spilled) == sorted(
                id(x) for x in live
            )
            # Age cut holds through paged unspill: no resident item is
            # younger than any spilled item.
            if sq.resident and sq.spilled:
                assert max(x[0] for x in sq.resident) <= min(
                    x[0] for x in sq.spilled
                )
            assert 0.0 <= sq.spilled_fraction <= 1.0
        drained = sq.drain()
        assert sorted(x[2] for x in drained) == sorted(x[2] for x in live)
        assert sq.nbytes == 0.0 and sq.size == 0 and not sq

    def test_unspill_oldest_returns_strictly_oldest_first(self):
        sq = _mk_spillq()
        for i, t in enumerate([0.0, 1.0, 2.0, 3.0, 4.0]):
            sq.push((t, 10.0, i))
        sq.spill_youngest(0.8)  # arrivals 1..4 spilled (40 of 50 bytes)
        assert [x[0] for x in sq.spilled] == [1.0, 2.0, 3.0, 4.0]
        # A 25 B grant covers exactly the two OLDEST spilled items; the
        # third (10 B) would overshoot and stays on host.
        assert sq.unspill_oldest(budget_bytes=25.0) == 2
        assert [x[0] for x in sq.resident] == [0.0, 1.0, 2.0]
        assert [x[0] for x in sq.spilled] == [3.0, 4.0]
        assert sq.spilled_bytes == 20.0

    def test_grant_smaller_than_oldest_item_pages_nothing(self):
        """Oldest-first is strict: a younger, smaller item is never paged
        in ahead of an older one that does not fit."""
        sq = _mk_spillq()
        sq.push((0.0, 10.0, 0))
        sq.push((1.0, 30.0, 1))  # old, big
        sq.push((2.0, 5.0, 2))  # young, small — would fit, must still wait
        sq.spill_youngest(0.7)
        assert [x[2] for x in sq.spilled] == [1, 2]
        assert sq.unspill_oldest(budget_bytes=8.0) == 0
        assert [x[2] for x in sq.spilled] == [1, 2]
        assert sq.resident_bytes == 10.0

    def test_max_items_bound(self):
        sq = _mk_spillq()
        for i in range(5):
            sq.push((float(i), 4.0, i))
        sq.spill_youngest(1.0)
        assert sq.unspill_oldest(max_items=2) == 2
        assert [x[2] for x in sq.resident] == [0, 1]


class TestApplySpillBytes:
    def _wm(self, probe_bytes=2.0):
        wm = WorkloadManager(_identity_range, probe_bytes=probe_bytes)
        # bucket 1 oldest ... bucket 4 youngest; 5 units x 1 object each
        # (multiple units per queue so partial spill has a boundary to cut)
        qid = 0
        for i, b in enumerate([1, 2, 3, 4]):
            for j in range(5):
                wm.submit(_mk_query(qid, float(i) + 0.1 * j, [b]))
                qid += 1
        return wm

    def test_spills_exactly_the_deficit_youngest_first(self):
        wm = self._wm()  # 4 queues x 10 B = 40 B resident
        cfg = ControlConfig(spill_budget_bytes=25.0)
        changed = apply_spill(wm, ControlVector(0.5, 1, True), cfg)
        # deficit 15 B: bucket 4 spills whole (10 B), bucket 3 partially
        # (5 B -> rounds up at unit granularity but keeps oldest resident).
        assert changed == [4, 3]
        assert wm.spilled_fraction(4) == 1.0
        assert 0.0 < wm.spilled_fraction(3) < 1.0
        assert wm.resident_bytes() <= 25.0
        assert not wm.is_spilled(1) and not wm.is_spilled(2)

    def test_oldest_queue_never_fully_spilled(self):
        wm = self._wm()
        cfg = ControlConfig(spill_budget_bytes=0.0)
        apply_spill(wm, ControlVector(0.5, 1, True), cfg)
        q1 = wm.queues[1]  # the oldest queue survives with its oldest unit
        assert q1.resident_size > 0
        assert wm.resident_bytes() == q1.resident_bytes

    def test_one_round_never_spills_and_unspills(self):
        """Within a single apply_spill call the walk is one-directional:
        engaged rounds only grow spilled bytes, disengaged rounds only
        shrink them (a paged grant may leave a bucket partially spilled)."""
        wm = self._wm()
        cfg = ControlConfig(spill_budget_bytes=25.0, spill_low_water=0.9)
        spilled_before = set(wm.spilled_buckets())
        changed = apply_spill(wm, ControlVector(0.5, 1, True), cfg)
        assert all(wm.is_spilled(b) for b in changed)
        assert spilled_before.issubset(set(wm.spilled_buckets()))
        # Drain enough that the disengaged round pages work back.
        wm.complete_bucket(1, 5.0)
        wm.complete_bucket(2, 5.0)
        before = {b: wm.queues[b].spilled_bytes for b in wm.spilled_buckets()}
        changed = apply_spill(wm, ControlVector(0.5, 1, False), cfg)
        assert changed
        for b in changed:
            assert wm.queues[b].spilled_bytes < before[b]  # only unspilled

    def test_paged_unspill_fills_exactly_the_low_water_headroom(self):
        """The disengaged walk grants only ``low - resident`` bytes in
        total, so a disengaged round can never push residency back above
        the low-water mark, let alone the budget."""
        wm = self._wm()  # 4 queues x 10 B
        cfg = ControlConfig(spill_budget_bytes=25.0, spill_low_water=0.8)
        apply_spill(wm, ControlVector(0.5, 1, True), cfg)  # spill to <= 25
        wm.complete_bucket(1, 5.0)  # resident 24 -> 14; low = 20
        resident_before = wm.resident_bytes()
        changed = apply_spill(wm, ControlVector(0.5, 1, False), cfg)
        assert changed
        assert wm.resident_bytes() <= 25.0 * 0.8 + 1e-9
        assert wm.resident_bytes() > resident_before  # it did page work in

    def test_unspill_grants_priced_by_t_spill_per_byte(self):
        """Highest wait-cost-per-byte pages in first: a small spilled
        queue clears its whole T_spill surcharge with few granted bytes,
        so it outranks a big older one; unpriced (no cost model or
        T_spill == 0) falls back to oldest-first."""
        def build():
            wm = WorkloadManager(_identity_range, probe_bytes=2.0)
            for j in range(10):  # bucket 1: old and big (20 B)
                wm.submit(_mk_query(j, 0.1 * j, [1]))
            for j in range(2):  # bucket 2: young and small (4 B)
                wm.submit(_mk_query(100 + j, 5.0 + 0.1 * j, [2]))
            wm.spill_bucket(1, 0.5)  # 10 B spilled
            wm.spill_bucket(2, 0.6)  # 2 B spilled
            return wm

        # low = 14, resident = 12 -> 2 B of headroom: exactly one grant.
        cfg = ControlConfig(spill_budget_bytes=17.5, spill_low_water=0.8)
        vec = ControlVector(0.5, 1, False)
        priced = build()
        changed = apply_spill(priced, vec, cfg, cost=CostModel(T_spill=0.4))
        assert changed == [2]  # T_spill/4 per byte beats T_spill/20
        assert not priced.is_spilled(2)
        unpriced = build()
        changed = apply_spill(unpriced, vec, cfg, cost=None)
        assert changed == [1]  # oldest-first when unpriced
        assert unpriced.is_spilled(2)

    def test_tenant_filter_only_touches_own_buckets(self):
        wm = WorkloadManager(_identity_range, probe_bytes=1.0)
        wm.submit(_mk_query(0, 0.0, [1] * 6, tenant="interactive"))
        wm.submit(_mk_query(1, 1.0, [2] * 6, tenant="batch"))
        wm.submit(_mk_query(2, 2.0, [3] * 6, tenant="batch"))
        cfg = ControlConfig(spill_budget_bytes=4.0)
        only = lambda b: wm.tenant_of_bucket(b) == "batch"
        changed = apply_spill(
            wm, ControlVector(0.5, 1, True), cfg, only=only
        )
        assert changed and all(wm.tenant_of_bucket(b) == "batch" for b in changed)
        assert not wm.is_spilled(1)  # interactive untouched


class TestServingQueueMirrorsCore:
    """The serving engine's _AdapterQueue and the core WorkloadQueue now
    share one ``SpillQueue`` implementation — these properties pin the
    serving instantiation (Request items, prompt-byte pricing with the
    zero-prompt floor) to the same invariants (conservation, age-cut,
    idempotent unspill, exact 0/1 sigma endpoints)."""

    def _workload(self, rng, n=20, n_adapters=4, probe_bytes=2.0):
        from repro.serving import AdapterWorkload, Request

        aw = AdapterWorkload(range(n_adapters), probe_bytes=probe_bytes)
        t = 0.0
        for i in range(n):
            t += float(rng.exponential(0.1))
            aw.push(Request(i, int(rng.integers(0, n_adapters)), t,
                            int(rng.integers(4, 64)), 16))
        return aw

    @given(st.integers(0, 10_000), st.floats(0.05, 1.0))
    @settings(max_examples=25, deadline=None)
    def test_conservation_and_age_cut_under_churn(self, seed, frac):
        from repro.serving import Request

        rng = np.random.default_rng(seed)
        aw = self._workload(rng)
        rid = 1000
        for _ in range(25):
            a = int(rng.integers(0, 4))
            op = rng.random()
            if op < 0.4:
                aw.spill_bucket(a, float(rng.uniform(0.05, 1.0)) if op < 0.25 else frac)
            elif op < 0.5:
                aw.unspill_bucket(a)
            elif op < 0.6:  # paged unspill: grants leave partial suffixes
                aw.unspill_bucket(a, budget_bytes=float(rng.uniform(0, 80)))
            elif op < 0.85:  # out-of-order arrivals + zero-length prompts
                aw.push(Request(rid, a, float(rng.uniform(0, 3)),
                                int(rng.integers(0, 64)), 16))
                rid += 1
            else:
                aw.retire(a)
            for q in aw.nonempty_queues():
                assert q.resident_bytes + q.spilled_bytes == pytest.approx(
                    q.nbytes, rel=1e-12
                )
                assert q.resident_size + len(q.spilled_requests) == q.size
                assert 0.0 <= q.spilled_fraction <= 1.0
                if q.requests and q.spilled_requests:
                    assert max(r.arrival_time for r in q.requests) <= min(
                        r.arrival_time for r in q.spilled_requests
                    )

    def test_unspill_idempotent_and_sigma_endpoints(self):
        from repro.serving import AdapterWorkload, Request

        aw = AdapterWorkload([0], probe_bytes=2.0)
        for i, t in enumerate([0.0, 1.0, 2.0]):
            aw.push(Request(i, 0, t, 10, 16))
        q = aw.queues[0]
        assert q.spilled_fraction == 0.0
        aw.spill_bucket(0)  # whole queue
        assert q.spilled_fraction == 1.0  # exact endpoint
        assert aw.unspill_bucket(0)
        assert not aw.unspill_bucket(0)  # idempotent
        assert q.spilled_fraction == 0.0 and q.resident_size == 3
        aw.spill_bucket(0, 0.4)
        assert 0.0 < q.spilled_fraction < 1.0
        assert q.requests[0].arrival_time == 0.0  # oldest stays resident


class TestZeroByteFloor:
    """§6 budget free-riders: units must never price at 0 bytes, or they
    escape the budget and sigma entirely (a zero-length serving prompt
    still holds request state; ``CostModel.min_unit_bytes`` floors it)."""

    def test_zero_length_prompts_cannot_free_ride_the_budget(self):
        from repro.serving import AdapterWorkload, Request

        aw = AdapterWorkload([0], probe_bytes=4.0, min_unit_bytes=2.0)
        for i, t in enumerate([0.0, 1.0, 2.0]):
            aw.push(Request(i, 0, t, 0, 16))  # zero-length prompts
        q = aw.queues[0]
        assert q.nbytes == 6.0  # 3 x the 2 B floor, not 0
        assert aw.spill_bucket(0, 0.5)  # spillable: there are bytes to move
        assert q.spilled_bytes > 0.0
        assert 0.0 < q.spilled_fraction < 1.0

    def test_core_units_floored_at_min_unit_bytes(self):
        wm = WorkloadManager(_identity_range, probe_bytes=0.0, min_unit_bytes=3.0)
        wm.submit(_mk_query(0, 0.0, [1, 1]))
        q = wm.queues[1]
        assert q.nbytes == 3.0  # floored, not 2 * 0.0
        assert wm.spill_bucket(1)
        assert q.spilled_fraction == 1.0

    def test_floor_does_not_alter_nonzero_prices(self):
        from repro.serving import AdapterWorkload, Request

        aw = AdapterWorkload([0], probe_bytes=4.0)  # default 1 B floor
        aw.push(Request(0, 0, 0.0, 10, 16))
        assert aw.queues[0].nbytes == 40.0


class TestWholesaleUnspillOvershoot:
    """The §6 bugfix this PR pins: wholesale unspill pages a queue's whole
    spilled suffix back in one shot, which can immediately re-exceed
    ``spill_budget_bytes`` and re-engage spill next round — oscillating
    across the hysteresis band.  The paged protocol pages back only what
    fits; the legacy behavior survives behind ``wholesale_unspill``
    (where this suite demonstrates the overshoot it reintroduces)."""

    BUDGET = 1_000.0
    REQ_BYTES = 100.0  # prompt_len 10 x kv_bytes_per_token 10

    def _run_serving(self, wholesale):
        from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig

        cfg = ServeConfig(
            policy="liferaft", adaptive=True, max_batch=4, decode_quantum=16,
            spill_budget_bytes=self.BUDGET, spill_penalty_s=0.05,
            kv_bytes_per_token=10.0, control_halflife_s=1.0,
            wholesale_unspill=wholesale,
        )
        eng = LifeRaftEngine([AdapterSpec(a, 8 << 30) for a in range(3)], cfg)
        rng = np.random.default_rng(5)
        t, reqs = 0.0, []
        for i in range(80):  # ~8 kB of prompt state vs a 1 kB budget
            t += float(rng.exponential(0.002))
            reqs.append(Request(i, int(rng.integers(0, 3)), t, 10, 16))
        samples = []
        prev_spilled = [0.0]

        def on_round(outcome):
            spilled = sum(
                q.spilled_bytes for q in eng.workload.queues.values()
            )
            samples.append(
                {
                    "resident": eng.workload.resident_bytes(),
                    "unspilled": spilled < prev_spilled[0] - 1e-9,
                }
            )
            prev_spilled[0] = spilled

        eng.loop.on_round = on_round
        summary = eng.run(reqs)
        assert summary["n_completed"] == len(reqs)
        return samples

    def _bound(self):
        # The §6 floors: servicing pages in at most one batch (max_batch
        # = 4) of spilled requests (they were decoded — their state is on
        # device by definition), plus one oldest-unit no-starvation floor
        # per adapter queue (3 adapters).  bench_adaptive's
        # unspill_oscillation gate pins the same budget + (max_batch +
        # n_adapters) * req_bytes formula.
        return self.BUDGET + (4 + 3) * self.REQ_BYTES

    def test_no_above_budget_round_follows_a_paged_unspill(self):
        """The pinned regression: with the paged protocol, no scheduling
        round that paged spilled work back in ends above the budget (+ the
        service-batch and oldest-unit floors)."""
        samples = self._run_serving(wholesale=False)
        unspill_rounds = [s for s in samples if s["unspilled"]]
        assert unspill_rounds, "scenario must exercise unspill"
        bad = [s for s in unspill_rounds if s["resident"] > self._bound()]
        assert not bad, bad

    def test_wholesale_flag_reproduces_the_overshoot(self):
        """The legacy mode is preserved behind the explicit flag — and it
        demonstrably overshoots on the same trace, which is why it is no
        longer the default (this is the bound's teeth)."""
        samples = self._run_serving(wholesale=True)
        unspill_rounds = [s for s in samples if s["unspilled"]]
        assert any(s["resident"] > self._bound() for s in unspill_rounds)

    def test_retire_pages_back_only_the_serviced_requests(self):
        """Servicing a spilled adapter pages in exactly the batch it
        decoded — not the whole suffix (the overshoot's mechanism)."""
        from repro.serving import AdapterWorkload, Request

        aw = AdapterWorkload([0], probe_bytes=10.0)
        for i in range(10):
            aw.push(Request(i, 0, float(i), 10, 32))  # 100 B each
        aw.spill_bucket(0, 0.8)  # 8 youngest spilled
        q = aw.queues[0]
        assert len(q.spilled_requests) == 8
        batch = aw.take(0, 4)  # 2 resident + the 2 oldest spilled
        for r in batch:
            r.tokens_done = 16  # serviced but unfinished
        aw.retire(0, batch)
        # Only the two serviced spilled requests paged back in.
        assert len(q.requests) == 4 and len(q.spilled_requests) == 6
        assert q.spilled_bytes == 600.0
        assert aw.is_spilled(0)  # suffix remains -> still pays sigma
        # Wholesale flag restores the legacy page-everything behavior.
        aw_legacy = AdapterWorkload([0], probe_bytes=10.0, wholesale_unspill=True)
        for i in range(10):
            aw_legacy.push(Request(i, 0, float(i), 10, 32))
        aw_legacy.spill_bucket(0, 0.8)
        batch = aw_legacy.take(0, 4)
        aw_legacy.retire(0, batch)
        assert not aw_legacy.queues[0].spilled_requests
        assert not aw_legacy.is_spilled(0)


class TestSpillHysteresis:
    @given(st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_loop_hysteresis_transitions_only_on_crossings(self, seed):
        """The spill bit changes only when a threshold is crossed: engage
        requires resident > budget, disengage requires pending <= low
        water.  In particular it cannot oscillate within one round."""
        rng = np.random.default_rng(seed)
        budget, low_water = 1000.0, 0.6
        loop = ControlLoop(ControlConfig(
            spill_budget_bytes=budget, spill_low_water=low_water,
        ))
        prev = False
        for _ in range(60):
            pending = float(rng.uniform(0, 2500))
            resident = float(rng.uniform(0, pending)) if pending else 0.0
            vec = loop.update(Telemetry(
                0.0, 0.0, int(pending), int(resident), 3, 0.0, 0.0, 0.5,
                pending_bytes=pending, resident_bytes=resident,
            ))
            if vec.spill and not prev:
                assert resident > budget  # engage only above budget
            if prev and not vec.spill:
                assert pending <= budget * low_water  # disengage only below
            prev = vec.spill

    @given(st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_plane_hysteresis_per_tenant(self, seed):
        rng = np.random.default_rng(seed)
        plane = TenantControlPlane(
            [
                TenantPolicy("interactive", ControlConfig(spill_low_water=0.5)),
                TenantPolicy("batch", ControlConfig(spill_low_water=0.5), weight=2.0),
            ],
            global_budget_bytes=900.0,
        )
        prev = {"interactive": False, "batch": False}
        for _ in range(40):
            tels = {}
            for t in ("interactive", "batch"):
                pend = float(rng.uniform(0, 1500))
                res = float(rng.uniform(0, pend)) if pend else 0.0
                tels[t] = Telemetry(
                    0.0, 0.0, int(pend), int(res), 2, 0.0, 0.0, 0.5,
                    pending_bytes=pend, resident_bytes=res,
                )
            vecs = plane.update(tels)
            # Arbiter conservation: grants never exceed the global budget.
            assert sum(plane.granted_bytes.values()) <= 900.0 + 1e-9
            for t, vec in vecs.items():
                grant = plane.granted_bytes[t]
                if vec.spill and not prev[t]:
                    assert tels[t].resident_bytes > grant
                if prev[t] and not vec.spill:
                    assert tels[t].pending_bytes <= grant * 0.5 + 1e-9
                prev[t] = vec.spill

    def test_waterfill_work_conserving_under_contention(self):
        plane = TenantControlPlane(
            [
                TenantPolicy("a", weight=1.0),
                TenantPolicy("b", weight=3.0),
            ],
            global_budget_bytes=400.0,
        )
        grants = plane._waterfill({"a": 1000.0, "b": 1000.0})
        assert grants == {"a": 100.0, "b": 300.0}  # pure weighted split
        grants = plane._waterfill({"a": 50.0, "b": 1000.0})
        # a is satisfied; b absorbs the surplus (work-conserving).
        assert grants["a"] == 50.0 and grants["b"] == 350.0
        # Under-demand: grants still sum to the whole budget (the slack on
        # top of demand is what lets the low-water disengage test pass).
        grants = plane._waterfill({"a": 10.0, "b": 20.0})
        assert sum(grants.values()) == pytest.approx(400.0)
        assert grants["a"] >= 10.0 and grants["b"] >= 20.0

    def test_plane_spill_disengages_after_pressure_subsides(self):
        """Regression: grants are waterfilled from *pending* bytes.  With
        resident-bytes demand the grant chased post-spill residency and
        `pending <= grant*low_water` could never pass — spilled work was
        stranded on host until fully drained by service."""
        plane = TenantControlPlane(
            [TenantPolicy("t", ControlConfig(spill_low_water=0.8))],
            global_budget_bytes=100.0,
        )

        def tel(pending, resident):
            return {"t": Telemetry(0.0, 0.0, int(pending), int(resident), 2,
                                   0.0, 0.0, 0.5, pending_bytes=pending,
                                   resident_bytes=resident)}

        assert plane.update(tel(200.0, 200.0))["t"].spill  # overload: engage
        # Enforcement spilled down to the grant; backlog starts draining.
        assert plane.update(tel(150.0, 100.0))["t"].spill  # still too much
        vec = plane.update(tel(40.0, 40.0))  # fits comfortably under budget
        assert not vec["t"].spill  # must disengage so work pages back in

    def test_unknown_tenant_class_joins_the_budget_books(self):
        """Regression: telemetry for a class with no TenantPolicy must not
        escape the arbiter (unbounded resident state outside the budget).
        Unknown classes are lazily registered with a default policy."""
        plane = TenantControlPlane(
            [TenantPolicy("known")], global_budget_bytes=100.0
        )
        tels = {
            "known": Telemetry(0.0, 0.0, 10, 10, 1, 0.0, 0.0, 0.5,
                               pending_bytes=10.0, resident_bytes=10.0),
            "stray": Telemetry(0.0, 0.0, 500, 500, 1, 0.0, 0.0, 0.5,
                               pending_bytes=500.0, resident_bytes=500.0),
        }
        vecs = plane.update(tels)
        assert "stray" in vecs and "stray" in plane.granted_bytes
        assert vecs["stray"].spill  # over its grant -> enforced
        assert sum(plane.granted_bytes.values()) == pytest.approx(100.0)

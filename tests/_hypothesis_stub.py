"""Minimal stand-in for the `hypothesis` API used by this test suite.

Installed into ``sys.modules`` by ``conftest.py`` ONLY when the real
hypothesis package is unavailable (it is a dev requirement — see
requirements-dev.txt).  Supports the subset the suite uses:

  * ``strategies.integers/floats/lists``
  * ``@given(...)`` — runs boundary examples first (min/max of every
    strategy, so exact-endpoint assertions like ``alpha == 0.0`` are
    exercised), then deterministic pseudo-random draws
  * ``@settings(max_examples=..., deadline=...)``

It performs no shrinking and no example database — it exists so the
tier-1 suite collects and runs green in hermetic environments.
"""
from __future__ import annotations

import inspect
import itertools
import sys
import types

_DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    def __init__(self, edges, draw):
        self._edges = edges
        self._draw = draw

    def edges(self):
        return list(self._edges)

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return SearchStrategy(
        [min_value, max_value],
        lambda rng: int(rng.integers(min_value, max_value + 1)),
    )


def floats(min_value, max_value):
    return SearchStrategy(
        [min_value, max_value, (min_value + max_value) / 2.0],
        lambda rng: float(rng.uniform(min_value, max_value)),
    )


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]

    edge = [elements.edges()[0]] * max(min_size, 1)
    return SearchStrategy([edge[:min_size] if min_size == 0 else edge], draw)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    def deco(fn):
        max_examples = getattr(fn, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)

        def wrapper(*args, **kwargs):
            import numpy as np

            examples = list(itertools.product(*(s.edges() for s in strategies)))
            examples = examples[:max_examples]
            rng = np.random.default_rng(0)
            while len(examples) < max_examples:
                examples.append(tuple(s.draw(rng) for s in strategies))
            for ex in examples:
                fn(*args, *ex, **kwargs)

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution: expose only the leading (non-drawn) params, e.g. self.
        params = list(inspect.signature(fn).parameters.values())
        keep = params[: len(params) - len(strategies)]
        wrapper.__signature__ = inspect.Signature(keep)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def _build_modules():
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists"):
        setattr(strat, name, globals()[name])
    strat.SearchStrategy = SearchStrategy
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.__version__ = "0.0.stub"
    return hyp, strat


def install():
    """Register the stub as ``hypothesis`` if the real package is missing."""
    try:
        import hypothesis  # noqa: F401

        return False
    except ImportError:
        hyp, strat = _build_modules()
        sys.modules["hypothesis"] = hyp
        sys.modules["hypothesis.strategies"] = strat
        return True

"""Golden-trace regression tests + the per-tenant starvation bound.

The goldens in ``tests/golden/`` are recorded DispatchLoop decision logs
(see tests/replay.py).  The ``PRE_REFACTOR_SCENARIOS`` were recorded
*before* the multi-tenant control plane / partial-spill refactor, so
their bit-identity proves the refactor moved no single-tenant decision:
the per-group heap rework, sigma fractions, resident-prefix entries and
per-bucket alpha plumbing all collapse to the historical arithmetic when
one tenant runs.  ``sim_two_tenant`` was recorded at feature introduction
and pins the multi-tenant decisions against future drift.

Regenerate deliberately with ``PYTHONPATH=src python tests/make_golden.py
<scenario>`` — a regenerated golden is a reviewed waiver of bit-identity,
never an accident.
"""
import pytest

import replay
from repro.core import CostModel, LifeRaftScheduler, simulate_batched


@pytest.mark.parametrize("name", sorted(replay.SCENARIOS))
def test_decision_log_matches_golden(name):
    golden_path = replay.GOLDEN_DIR / f"{name}.json"
    assert golden_path.exists(), (
        f"missing golden {golden_path}; run tests/make_golden.py {name}"
    )
    expect = replay.load_trace(golden_path)
    got = replay.SCENARIOS[name]()
    divergence = replay.diff_traces(expect, got)
    assert not divergence, "\n".join(
        [f"decision log diverged from golden {name}:"] + divergence
    )


@pytest.mark.parametrize("name", ["sim_spill_paged", "serving_spill_paged"])
def test_paged_spill_goldens_exercise_both_directions(name):
    """The paged-unspill goldens must actually pin the §6 paths they were
    recorded for: rounds that engage spill AND disengaged rounds that page
    work back in (otherwise drift in the paged protocol would go unseen)."""
    rounds = replay.load_trace(replay.GOLDEN_DIR / f"{name}.json")
    assert any(e["vector"][2] and e["spill_changed"] for e in rounds)
    assert any(not e["vector"][2] and e["spill_changed"] for e in rounds)


def test_prefetch_goldens_exercise_the_pipeline():
    """The prefetch-on goldens must pin what they were recorded for:
    residual-stall rounds (a demanded bucket caught mid-stage), and — on
    the simulator scenario — §6 rounds under the PRICED victim walk, so
    drift in the staging protocol or the pricing would move the trace."""
    sim = replay.load_trace(replay.GOLDEN_DIR / "sim_prefetch.json")
    assert any("stall" in e for e in sim)
    assert any(e["vector"][2] and e["spill_changed"] for e in sim)
    serving = replay.load_trace(replay.GOLDEN_DIR / "serving_prefetch.json")
    assert any("stall" in e for e in serving)


def test_diff_traces_reports_divergence():
    """The harness itself must catch a moved decision, not just agree."""
    base = replay.SCENARIOS["sim_raw_fused"]()
    mutated = [dict(e) for e in base]
    mutated[3] = dict(mutated[3])
    mutated[3]["decisions"] = [
        [d[0] + 1, d[1], d[2], d[3]] for d in mutated[3]["decisions"]
    ]
    out = replay.diff_traces(base, mutated)
    assert out and "round 3" in out[0]
    assert replay.diff_traces(base, base[:-1])  # length change detected


class TestPerTenantStarvation:
    """Paper §6 scenario: a batch flood must not starve interactive
    queries.  Under the per-tenant plane the interactive class pins
    alpha >= ALPHA_MIN, so an interactive bucket's normalized score is at
    least ALPHA_MIN * age/age_scale while any batch bucket scores at most
    ~1 (U_t_norm <= 1) + its own small age term — interactive therefore
    wins selection within an age_scale_ms-derived horizon.  The bound
    below is that horizon plus one worst-case fused round in flight."""

    ALPHA_MIN = 0.7  # interactive tenant's alpha floor (two_tenant_plane)
    ROUND_SLACK_S = 0.7  # one worst-case fused dispatch ahead of us

    def _bound_s(self, cost: CostModel) -> float:
        return cost.age_scale_ms / 1e3 / self.ALPHA_MIN + self.ROUND_SLACK_S

    def _run(self, seed, control=None, alpha=0.5):
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.1, probe_bytes=16.0)
        qs = replay.two_tenant_trace(
            seed, horizon=10.0, flood_gap=0.03, depth_lo=60, depth_hi=120
        )
        r = simulate_batched(
            qs, replay._identity_range,
            LifeRaftScheduler(cost, alpha, normalized=True), cost,
            cache_capacity=8, control=control,
        )
        return r, self._bound_s(cost)

    @pytest.mark.parametrize("seed", [41, 42, 43])
    def test_no_interactive_query_ages_past_bound(self, seed):
        r, bound = self._run(seed, control=replay.two_tenant_plane(60_000.0))
        stats = r.per_tenant["interactive"]
        assert stats["n"] > 0
        assert stats["max_response"] <= bound, (stats, bound)

    @pytest.mark.parametrize("seed", [41, 42, 43])
    def test_global_greedy_violates_the_bound(self, seed):
        """The bound has teeth: the same flood under one global greedy
        alpha starves interactive singletons past it (which is exactly why
        per-tenant alpha exists)."""
        r, bound = self._run(seed, alpha=0.0)
        assert r.per_tenant["interactive"]["max_response"] > bound

    def test_batch_throughput_not_sacrificed(self):
        """Isolation is not partitioning: with the plane active the batch
        class keeps >= 0.9x the aggregate throughput of the global greedy
        run (shared scheduling still amortizes the flood)."""
        r_mt, _ = self._run(41, control=replay.two_tenant_plane(60_000.0))
        r_greedy, _ = self._run(41, alpha=0.0)
        assert r_mt.query_throughput >= 0.9 * r_greedy.query_throughput

"""Property tests for the adaptive layer (paper §4) and the closed-loop
control plane: TradeoffTable curve lookup, AlphaController constraints,
SaturationEstimator convergence, ControlLoop feedback laws, §6 spill
enforcement, and the shared DispatchLoop end to end."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    AlphaController,
    BucketCache,
    ControlConfig,
    ControlLoop,
    ControlVector,
    CostModel,
    LifeRaftScheduler,
    NaiveLifeRaftScheduler,
    Query,
    SaturationEstimator,
    Telemetry,
    TradeoffPoint,
    TradeoffTable,
    WorkloadManager,
    apply_spill,
    run_policy,
)


def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def _mk_query(qid, t, buckets):
    ks = np.asarray(buckets, dtype=np.uint64)
    return Query(qid, t, ks, ks)


def _random_table(rng, n_curves, n_points):
    t = TradeoffTable()
    for _ in range(n_curves):
        sat = float(rng.uniform(0.01, 2.0))
        pts = [
            TradeoffPoint(
                alpha=float(a),
                throughput=float(rng.uniform(0.1, 2.0)),
                response=float(rng.uniform(0.5, 20.0)),
            )
            for a in np.linspace(0.0, 1.0, n_points)
        ]
        t.add(sat, pts)
    return t


# ---------------------------------------------------------------- TradeoffTable
class TestTradeoffTableProperties:
    @given(st.integers(0, 10_000), st.integers(1, 6), st.integers(2, 6),
           st.floats(0.0, 3.0))
    @settings(max_examples=40, deadline=None)
    def test_nearest_curve_lookup_returns_a_stored_curve(
        self, seed, n_curves, n_points, probe_sat
    ):
        rng = np.random.default_rng(seed)
        table = _random_table(rng, n_curves, n_points)
        curve = table.curve(probe_sat)
        stored = [table.curve(s) for s in table.saturations()]
        assert any(curve is c for c in stored)
        # ...and it is the curve at the *nearest* measured saturation.
        sats = table.saturations()
        nearest = min(sats, key=lambda s: abs(s - probe_sat))
        assert abs(sats[[table.curve(s) is curve for s in sats].index(True)]
                   - probe_sat) <= abs(nearest - probe_sat) + 1e-12

    @given(st.integers(0, 10_000), st.integers(1, 5), st.integers(2, 6),
           st.floats(0.0, 1.0), st.floats(0.0, 3.0))
    @settings(max_examples=40, deadline=None)
    def test_select_alpha_satisfies_throughput_tolerance(
        self, seed, n_curves, n_points, tolerance, probe_sat
    ):
        rng = np.random.default_rng(seed)
        table = _random_table(rng, n_curves, n_points)
        alpha = table.select_alpha(probe_sat, tolerance)
        pts = table.curve(probe_sat)
        tmax = max(p.throughput for p in pts)
        chosen = [p for p in pts if p.alpha == alpha]
        assert chosen, "selected alpha must be a stored point"
        assert chosen[0].throughput >= (1.0 - tolerance) * tmax - 1e-12
        # ...and has minimal response among the throughput-feasible points.
        ok = [p for p in pts if p.throughput >= (1.0 - tolerance) * tmax]
        assert chosen[0].response == min(p.response for p in ok)

    def test_empty_table_raises(self):
        with pytest.raises(ValueError):
            TradeoffTable().curve(0.5)


# ---------------------------------------------------------------- estimator
class TestSaturationEstimator:
    @given(st.integers(0, 10_000), st.integers(2, 80))
    @settings(max_examples=30, deadline=None)
    def test_rate_nonnegative_under_random_arrivals(self, seed, n):
        rng = np.random.default_rng(seed)
        est = SaturationEstimator(halflife_s=5.0)
        t = 0.0
        for _ in range(n):
            t += float(rng.exponential(0.3)) + 1e-6
            assert est.observe_arrival(t) >= 0.0
        assert est.rate >= 0.0

    @given(st.floats(0.05, 5.0))
    @settings(max_examples=20, deadline=None)
    def test_converges_on_constant_gap_stream(self, gap):
        """A constant-gap arrival stream must converge to rate 1/gap."""
        est = SaturationEstimator(halflife_s=2.0 * gap)
        t = 0.0
        for _ in range(400):
            t += gap
            est.observe_arrival(t)
        assert est.rate == pytest.approx(1.0 / gap, rel=1e-3)


# ---------------------------------------------------------------- controller
class TestAlphaControllerProperties:
    @given(st.integers(0, 10_000), st.integers(1, 4), st.floats(0.01, 0.3))
    @settings(max_examples=25, deadline=None)
    def test_alpha_stays_bounded_and_rate_limited(self, seed, n_curves, step):
        rng = np.random.default_rng(seed)
        ctl = AlphaController(
            _random_table(rng, n_curves, 4),
            tolerance=0.2,
            initial_alpha=0.5,
            max_step=step,
        )
        t, prev = 0.0, ctl.alpha
        for _ in range(60):
            t += float(rng.exponential(0.5)) + 1e-6
            a = ctl.update_on_arrival(t)
            assert 0.0 <= a <= 1.0
            assert abs(a - prev) <= step + 1e-12
            prev = a


# ---------------------------------------------------------------- control loop
def _tel(now=0.0, rate=0.0, pending=0, resident=None, n_queues=0,
         occupancy=0.0, hit=0.0, oldest=0.0):
    return Telemetry(
        now=now,
        arrival_rate=rate,
        pending_objects=pending,
        resident_objects=pending if resident is None else resident,
        n_queues=n_queues,
        oldest_age_ms=oldest,
        cache_hit_rate=hit,
        occupancy=occupancy,
    )


class TestControlLoop:
    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_vector_always_in_bounds(self, seed):
        rng = np.random.default_rng(seed)
        cfg = ControlConfig(fuse_k_max=6, alpha_step=0.15,
                            spill_budget_objects=500)
        loop = ControlLoop(cfg)
        prev_alpha = cfg.alpha_init
        for _ in range(50):
            vec = loop.update(_tel(
                now=float(rng.uniform(0, 100)),
                rate=float(rng.uniform(0, 5)),
                pending=int(rng.integers(0, 3000)),
                n_queues=int(rng.integers(0, 40)),
                occupancy=float(rng.uniform(0, 1)),
            ))
            assert 0.0 <= vec.alpha <= 1.0
            assert abs(vec.alpha - prev_alpha) <= cfg.alpha_step + 1e-12
            assert 1 <= vec.fuse_k <= cfg.fuse_k_max
            prev_alpha = vec.alpha

    def test_fallback_alpha_tracks_saturation(self):
        """Idle -> alpha drifts to arrival order; saturated -> data-driven."""
        loop = ControlLoop(ControlConfig(rate_knee=1.0, depth_knee=100.0,
                                         alpha_init=0.5))
        for _ in range(30):
            a_idle = loop.update(_tel(rate=0.0, pending=0)).alpha
        assert a_idle == pytest.approx(1.0)
        for _ in range(50):
            a_hot = loop.update(_tel(rate=5.0, pending=1000)).alpha
        assert a_hot == pytest.approx(0.0)

    def test_table_path_overrides_fallback(self):
        table = TradeoffTable()
        table.add(0.1, [TradeoffPoint(0.0, 1.0, 10.0),
                        TradeoffPoint(0.75, 0.95, 4.0)])
        loop = ControlLoop(ControlConfig(table=table, alpha_init=0.0,
                                         alpha_step=0.25))
        for _ in range(10):
            vec = loop.update(_tel(rate=0.1, pending=0))
        assert vec.alpha == pytest.approx(0.75)  # the table's pick, not 1.0

    def test_fuse_k_aimd(self):
        loop = ControlLoop(ControlConfig(fuse_k_max=8))
        # Underfull dispatches with pending breadth -> additive increase.
        for _ in range(5):
            k = loop.update(_tel(occupancy=0.1, n_queues=20)).fuse_k
        assert k == 6
        # Saturated dispatches -> back off.
        for _ in range(3):
            k = loop.update(_tel(occupancy=1.0, n_queues=20)).fuse_k
        assert k == 3
        # Never exceeds the number of nonempty queues.
        k = loop.update(_tel(occupancy=0.0, n_queues=2)).fuse_k
        assert k <= 2

    def test_spill_hysteresis(self):
        cfg = ControlConfig(spill_budget_objects=100, spill_low_water=0.5)
        loop = ControlLoop(cfg)
        assert not loop.update(_tel(pending=90)).spill
        assert loop.update(_tel(pending=150)).spill
        # Stays engaged until pending falls under the low-water mark.
        assert loop.update(_tel(pending=80)).spill
        assert not loop.update(_tel(pending=40)).spill

    def test_spill_disabled_without_budget(self):
        loop = ControlLoop(ControlConfig())
        assert not loop.update(_tel(pending=10**9)).spill


# ---------------------------------------------------------------- spill
class TestSpillEnforcement:
    def _workload(self):
        wm = WorkloadManager(_identity_range)
        # bucket 1: oldest, bucket 2: middle, bucket 3: youngest; 4 objs each
        for qid, (t, b) in enumerate([(0.0, 1), (1.0, 2), (2.0, 3)]):
            wm.submit(_mk_query(qid, t, [b] * 4))
        return wm

    def test_apply_spill_youngest_first_respects_budget(self):
        wm = self._workload()
        cfg = ControlConfig(spill_budget_objects=8)
        changed = apply_spill(wm, ControlVector(0.5, 1, True), cfg)
        assert changed == [3]  # youngest spilled first
        assert wm.is_spilled(3) and not wm.is_spilled(1)
        assert wm.resident_objects() == 8

    def test_apply_spill_never_spills_last_resident_queue(self):
        wm = self._workload()
        cfg = ControlConfig(spill_budget_objects=0)
        apply_spill(wm, ControlVector(0.5, 1, True), cfg)
        resident = [b for b in (1, 2, 3) if not wm.is_spilled(b)]
        assert len(resident) == 1

    def test_unspill_oldest_first_under_low_water(self):
        wm = self._workload()
        cfg = ControlConfig(spill_budget_objects=8, spill_low_water=1.0)
        apply_spill(wm, ControlVector(0.5, 1, True), cfg)
        assert wm.spilled_buckets() == [3]
        wm.complete_bucket(1, 3.0)  # backlog drops to 8 -> room to page in
        changed = apply_spill(wm, ControlVector(0.5, 1, False), cfg)
        assert changed == [3] and not wm.is_spilled(3)

    def test_service_pages_spilled_bucket_back_in(self):
        wm = self._workload()
        wm.spill_bucket(2)
        wm.complete_bucket(2, 5.0)
        assert not wm.is_spilled(2)

    def test_spilled_bucket_deprioritized_but_not_starved(self):
        """T_spill lowers a spilled bucket's U_t (greedy passes it over),
        while at alpha=1 age still reclaims it — §6 without starvation."""
        cost = CostModel(T_spill=10.0)
        wm = WorkloadManager(_identity_range)
        wm.submit(_mk_query(0, 0.0, [1] * 4))  # old
        wm.submit(_mk_query(1, 1.0, [2] * 4))  # young, same size
        cache = BucketCache(4)
        wm.spill_bucket(1)
        greedy = LifeRaftScheduler(cost, alpha=0.0)
        assert greedy.select(wm, cache, 2.0).bucket_id == 2
        aged = LifeRaftScheduler(cost, alpha=1.0)
        assert aged.select(wm, cache, 2.0).bucket_id == 1


# ---------------------------------------------------------------- end to end
class TestClosedLoopSimulation:
    def _trace(self, n=120, seed=0, buckets=40, gap=0.05):
        rng = np.random.default_rng(seed)
        qs, t = [], 0.0
        for qid in range(n):
            t += rng.exponential(gap)
            b = rng.integers(0, buckets)
            ks = np.full(rng.integers(2, 12), b, dtype=np.uint64)
            qs.append(Query(qid, t, ks, ks))
        return qs

    def test_adaptive_simulation_completes_all_queries(self):
        qs = self._trace()
        ctl = ControlLoop(ControlConfig(fuse_k_max=4,
                                        spill_budget_objects=300))
        r = run_policy("liferaft", qs, _identity_range,
                       CostModel(T_spill=0.4), alpha=0.25, control=ctl)
        assert r.n_queries == len(qs)
        assert r.policy.endswith("+ctl")
        assert ctl.rounds == r.n_dispatches

    def test_adaptive_fuses_dispatches_under_breadth(self):
        """With many shallow queues the controller must raise fuse_k, so
        dispatches land strictly below batches."""
        qs = self._trace(n=200, seed=3, buckets=120, gap=0.01)
        ctl = ControlLoop(ControlConfig(fuse_k_max=8))
        r = run_policy("liferaft", qs, _identity_range, CostModel(),
                       alpha=0.25, control=ctl)
        assert r.n_queries == len(qs)
        assert r.n_dispatches < r.n_batches

    def test_adaptive_decisions_identical_for_both_schedulers(self):
        """The control plane must not break naive/incremental equivalence:
        identical control configs over identical traces yield identical
        makespans and batch counts."""
        qs = self._trace(n=100, seed=5)
        results = []
        for policy in ("liferaft", "liferaft-naive"):
            ctl = ControlLoop(ControlConfig(fuse_k_max=4,
                                            spill_budget_objects=400))
            results.append(
                run_policy(policy, qs, _identity_range,
                           CostModel(T_spill=0.4), alpha=0.25, control=ctl,
                           normalized=True)
            )
        a, b = results
        assert a.makespan == b.makespan
        assert a.n_batches == b.n_batches
        assert a.mean_response == b.mean_response


# ---------------------------------------------------------------- serving
class TestServingAdaptive:
    def _trace(self, n=120, n_adapters=8, rate=200.0, seed=0):
        from repro.serving import Request

        rng = np.random.default_rng(seed)
        w = 1.0 / np.arange(1, n_adapters + 1) ** 1.5
        w /= w.sum()
        t, out = 0.0, []
        for i in range(n):
            t += rng.exponential(1.0 / rate)
            out.append(Request(i, int(rng.choice(n_adapters, p=w)), t,
                               int(rng.integers(8, 64)), 16))
        return out

    def test_adaptive_serving_completes_all(self):
        from repro.serving import AdapterSpec, LifeRaftEngine, ServeConfig

        eng = LifeRaftEngine(
            [AdapterSpec(i, 8 << 30) for i in range(8)],
            ServeConfig(policy="liferaft", adaptive=True, fuse_k_max=4,
                        spill_budget=48, spill_penalty_s=5e-3),
        )
        s = eng.run(self._trace())
        assert s["n_completed"] == 120
        assert s["adaptive"] is True
        assert not s["spilled"]  # drained -> everything paged back in

    def test_serving_runs_incremental_scheduler_path(self):
        """The serving engine's normalized default must ride the lazy-heap
        index (the old per-select façade forced the naive fallback)."""
        from repro.serving import AdapterSpec, LifeRaftEngine, ServeConfig

        eng = LifeRaftEngine(
            [AdapterSpec(i, 8 << 30) for i in range(4)],
            ServeConfig(policy="liferaft"),
        )
        assert not eng.scheduler._use_naive(eng.workload, eng.cache)
        eng.run(self._trace(n=40, n_adapters=4))
        assert eng.scheduler._wm is eng.workload  # bound once, kept bound

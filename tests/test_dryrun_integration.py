"""Integration: the dry-run machinery end-to-end on a small mesh.

Runs in a subprocess with 8 forced host devices (device count is locked at
first jax init, so the main pytest process must stay at 1 device).  Covers:
lower+compile of train/prefill/decode cells with reduced dims, roofline
term extraction, and the collective parser on real HLO.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax
from jax.sharding import Mesh
import numpy as np

from repro.launch import dryrun
from repro.launch.roofline import parse_collectives

# monkeypatch the production mesh down to the test size (2x4 / 2x2x2)
import repro.launch.mesh as mesh_mod

def small_mesh(*, multi_pod=False):
    if multi_pod:
        return jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    return jax.make_mesh((2, 4), ("data", "model"))

dryrun.make_production_mesh = small_mesh

SMALL = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
             d_ff=128, vocab_size=512, dtype="float32")
out = {}
for shape, extra in [("train_4k", {}), ("prefill_32k", {}), ("decode_32k", {})]:
    for mp in (False, True):
        lowered, meta, cfg, sh = dryrun.lower_cell(
            "codeqwen1.5-7b", shape, multi_pod=mp, overrides=dict(SMALL, **extra))
        compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: one dict per device
            cost = cost[0] if cost else {}
        coll = parse_collectives(compiled.as_text())
        key = f"{shape}|{'multi' if mp else 'single'}"
        out[key] = {
            "flops": float(cost.get("flops", 0.0)),
            "collectives": sum(coll.counts.values()),
            "wire": coll.wire_bytes_per_chip,
        }
# MoE + rule override path
lowered, *_ = dryrun.lower_cell(
    "mixtral-8x22b", "train_4k", overrides=dict(SMALL, n_experts=4, top_k=2,
                                                moe_d_ff=128, moe_dispatch="sort"),
    rule_overrides={"expert_cap": ("data",)})
lowered.compile()
out["moe_rule_override"] = True
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def dryrun_results():
    env = dict(os.environ)
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, timeout=900, env=env, cwd=root,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


class TestDryRunSmall:
    def test_all_kinds_compile_both_meshes(self, dryrun_results):
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            for mesh in ("single", "multi"):
                assert f"{shape}|{mesh}" in dryrun_results

    def test_train_has_collectives(self, dryrun_results):
        """Sharded training must produce gradient reductions in the HLO."""
        r = dryrun_results["train_4k|single"]
        assert r["collectives"] > 0
        assert r["wire"] > 0

    def test_multi_pod_shards_pod_axis(self, dryrun_results):
        """Multi-pod compile succeeds and moves bytes across the pod axis."""
        r = dryrun_results["train_4k|multi"]
        assert r["collectives"] > 0

    def test_flops_positive(self, dryrun_results):
        assert dryrun_results["train_4k|single"]["flops"] > 0

    def test_moe_rule_override_compiles(self, dryrun_results):
        assert dryrun_results["moe_rule_override"] is True


class TestCollectiveParser:
    def test_parse_synthetic_hlo(self):
        from repro.launch.roofline import parse_collectives

        hlo = """
  %ar = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %x), replica_groups=[4,8]<=[32], to_apply=%add
  %ag = f32[64,32]{1,0} all-gather(f32[8,32]{1,0} %y), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = s8[100]{0} collective-permute(s8[100]{0} %z), source_target_pairs={{0,1}}
"""
        st = parse_collectives(hlo)
        assert st.counts == {"all-reduce": 1, "all-gather": 1, "collective-permute": 1}
        ar_bytes = 8 * 128 * 2
        assert st.result_bytes["all-reduce"] == ar_bytes
        # ring all-reduce over g=8: 2*(7/8)*size
        expected = 2 * 7 / 8 * ar_bytes + 7 / 8 * (64 * 32 * 4) + 100
        assert st.wire_bytes_per_chip == pytest.approx(expected, rel=0.01)

    def test_start_done_counted_once(self):
        from repro.launch.roofline import parse_collectives

        hlo = """
  %s = bf16[16]{0} all-gather-start(bf16[2]{0} %x), replica_groups={{0,1,2,3,4,5,6,7}}
  %d = bf16[16]{0} all-gather-done(bf16[16]{0} %s)
"""
        st = parse_collectives(hlo)
        assert st.counts.get("all-gather", 0) == 1

"""Unit + property tests for space-filling curves."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import sfc


class TestHTM:
    def test_root_ids_in_range(self):
        pts = sfc.unit_vectors(500, seed=0)
        ids = sfc.htm_id(pts, level=0)
        assert ((ids >= 8) & (ids < 16)).all()

    def test_level_bit_layout(self):
        pts = sfc.unit_vectors(100, seed=1)
        for level in (0, 3, 7, 14):
            ids = sfc.htm_id(pts, level=level)
            lo, hi = 8 * 4**level, 16 * 4**level
            assert (ids >= lo).all() and (ids < hi).all()
            assert sfc.htm_level_of(int(ids[0])) == level

    def test_hierarchy_consistency(self):
        """Parent id at level L-1 is the child id >> 2."""
        pts = sfc.unit_vectors(200, seed=2)
        deep = sfc.htm_id(pts, level=8)
        shallow = sfc.htm_id(pts, level=7)
        np.testing.assert_array_equal(deep >> np.uint64(2), shallow)

    def test_spatial_locality(self):
        """Perturbed points land in the same (or adjacent) deep trixel."""
        rng = np.random.default_rng(3)
        pts = sfc.unit_vectors(100, seed=3)
        eps = pts + 1e-9 * rng.normal(size=pts.shape)
        eps /= np.linalg.norm(eps, axis=1, keepdims=True)
        a = sfc.htm_id(pts, level=10)
        b = sfc.htm_id(eps, level=10)
        assert (a == b).mean() > 0.95

    def test_partition_is_total(self):
        """Every point gets exactly one id; counts cover all 8 roots."""
        pts = sfc.unit_vectors(4000, seed=4)
        roots = sfc.htm_id(pts, level=0)
        assert len(np.unique(roots)) == 8

    def test_level14_fits_32bits(self):
        pts = sfc.unit_vectors(64, seed=5)
        ids = sfc.htm_id(pts, level=14)
        assert ids.max() < 2**32  # the paper's 32-bit HTM ids


class TestMorton:
    @given(
        st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=50),
        st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=50),
    )
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_2d(self, xs, ys):
        n = min(len(xs), len(ys))
        x = np.array(xs[:n], dtype=np.uint64)
        y = np.array(ys[:n], dtype=np.uint64)
        code = sfc.morton2d(x, y)
        x2, y2 = sfc.morton2d_decode(code)
        np.testing.assert_array_equal(x, x2)
        np.testing.assert_array_equal(y, y2)

    def test_monotone_along_axis(self):
        x = np.arange(100, dtype=np.uint64)
        z = np.zeros(100, dtype=np.uint64)
        codes = sfc.morton2d(x, z)
        assert (np.diff(codes.astype(np.int64)) > 0).all()

    def test_3d_distinct(self):
        rng = np.random.default_rng(0)
        x, y, z = (rng.integers(0, 2**20, 1000).astype(np.uint64) for _ in range(3))
        codes = sfc.morton3d(x, y, z)
        # Collisions only if (x,y,z) collide
        _, counts = np.unique(codes, return_counts=True)
        tuples = set(zip(x.tolist(), y.tolist(), z.tolist()))
        assert (counts > 1).sum() <= 1000 - len(tuples)


class TestConversions:
    def test_radec_poles(self):
        v = sfc.radec_to_unit(np.array([0.0]), np.array([90.0]))
        np.testing.assert_allclose(v, [[0, 0, 1]], atol=1e-12)

    def test_radec_unit_norm(self):
        rng = np.random.default_rng(1)
        ra = rng.uniform(0, 360, 100)
        dec = rng.uniform(-90, 90, 100)
        v = sfc.radec_to_unit(ra, dec)
        np.testing.assert_allclose(np.linalg.norm(v, axis=1), 1.0, atol=1e-12)

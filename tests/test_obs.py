"""Observability layer (PR 10): metrics registry, round tracer, exporters.

The layer's whole contract is *taps-only*: it consumes the existing
side-channel taps and never touches the decision path.  The tests here
pin each clause of that contract:

  * golden bit-identity — every recorded scenario replays identically
    with obs ON (and the obs tap demonstrably fired);
  * histogram bucket edges — Prometheus ``le`` semantics, overflow,
    negative values, quantile clamping, ladder-mismatch errors;
  * snapshot determinism — two identical virtual-clocked runs produce
    *equal* snapshot dicts and Prometheus text;
  * Perfetto export — valid JSON, one named track per shard, and the
    steal arrows (instant + s/f flow pair) for the steal golden;
  * lazy import — with ``obs=`` left off, ``repro.obs`` is never
    imported (subprocess check);
  * daemon endpoints — journal append/fsync histograms, admission
    verdict counters, metrics_text/metrics_snapshot, and their empty
    obs-off fallbacks;
  * ControlExplain — vector changes carry the trigger-signal reason.
"""
import json
import os
import pathlib
import subprocess
import sys

import pytest

import replay
from repro.core import (
    AdmissionController,
    AdmissionQuota,
    AdmissionRejected,
)
from repro.obs import MetricsRegistry, Observability
from repro.serving import (
    AdapterSpec,
    LifeRaftEngine,
    Request,
    ServeConfig,
    ServiceDaemon,
    ServingHost,
)

REPO = pathlib.Path(__file__).resolve().parent.parent

_MEMO = {}


def _obs_run(name):
    """One obs-ON run of a recorded scenario, shared across tests."""
    if name not in _MEMO:
        obs = Observability()
        entries = replay.SCENARIOS[name](obs=obs)
        _MEMO[name] = (obs, entries)
    return _MEMO[name]


# ------------------------------------------------------- golden bit-identity
@pytest.mark.parametrize("name", sorted(replay.SCENARIOS))
def test_goldens_bit_identical_with_obs_on(name):
    """The acceptance bar: observability must be a pure observer — the
    decision log with obs attached diffs empty against the golden."""
    obs, got = _obs_run(name)
    expect = replay.load_trace(replay.GOLDEN_DIR / f"{name}.json")
    divergence = replay.diff_traces(expect, got)
    assert not divergence, "\n".join(
        [f"obs-on decision log diverged from golden {name}:"] + divergence
    )
    # ... and obs was actually live, not silently detached.
    rounds = _obs_run(name)[0].snapshot()["metrics"]["liferaft_rounds_total"]
    assert sum(s["value"] for s in rounds["series"]) > 0


# ------------------------------------------------------------ histogram edges
class TestHistogramEdges:
    def test_le_semantics_overflow_and_negatives(self):
        reg = MetricsRegistry()
        h = reg.histogram("t_seconds", "test ladder", buckets=(1.0, 2.0, 5.0))
        for v in (0.5, 1.0, 1.5, 5.0, 7.0, -1.0):
            h.observe(v)
        cum = dict(h.cumulative())
        # le=1.0 holds 0.5, the exact bound 1.0, and the negative.
        assert cum[1.0] == 3
        assert cum[2.0] == 4
        assert cum[5.0] == 5  # 5.0 lands IN le=5.0, not overflow
        assert cum["+Inf"] == 6
        assert h.count == 6
        assert h.sum == pytest.approx(14.0)

    def test_quantiles_interpolate_and_clamp(self):
        reg = MetricsRegistry()
        h = reg.histogram("q_seconds", "", buckets=(1.0, 2.0, 5.0))
        for v in (0.5, 1.0, 1.5, 5.0, 7.0, -1.0):
            h.observe(v)
        # Median exhausts the first bucket exactly -> its upper bound.
        assert h.quantile(0.5) == pytest.approx(1.0)
        # Overflow mass clamps to the last finite bound.
        assert h.quantile(1.0) == pytest.approx(5.0)

    def test_empty_histogram_quantile_is_zero(self):
        reg = MetricsRegistry()
        assert reg.histogram("e_seconds", "").quantile(0.95) == 0.0

    def test_bucket_ladder_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.histogram("m_seconds", "", buckets=(1.0, 2.0))
        with pytest.raises(ValueError, match="bucket ladder mismatch"):
            reg.histogram("m_seconds", "", buckets=(1.0, 3.0))

    def test_type_mismatch_raises(self):
        reg = MetricsRegistry()
        reg.histogram("x_seconds", "")
        with pytest.raises(ValueError, match="already registered"):
            reg.counter("x_seconds", "")

    def test_unsorted_bounds_rejected(self):
        reg = MetricsRegistry()
        with pytest.raises(ValueError, match="ascending"):
            reg.histogram("bad_seconds", "", buckets=(2.0, 1.0))


# ------------------------------------------------------- snapshot determinism
def test_virtual_clock_snapshot_is_run_to_run_identical():
    """Nothing wall-clock may enter the registry on virtual taps: a rerun
    of the same scenario yields an *equal* snapshot and Prometheus text."""
    fresh = Observability()
    replay.SCENARIOS["serving_adaptive"](obs=fresh)
    memo = _obs_run("serving_adaptive")[0]
    assert fresh.snapshot() == memo.snapshot()
    assert fresh.prometheus() == memo.prometheus()


# ------------------------------------------------------------ perfetto export
class TestPerfetto:
    def _doc(self):
        doc = _obs_run("sim_shard_steal")[0].perfetto()
        # must survive a JSON round-trip (the artifact CI uploads)
        return json.loads(json.dumps(doc))

    def test_one_named_track_per_shard(self):
        evs = self._doc()["traceEvents"]
        names = [
            e for e in evs
            if e["ph"] == "M" and e["name"] == "thread_name"
        ]
        assert sorted(e["tid"] for e in names) == [0, 1, 2, 3]
        assert {e["args"]["name"] for e in names} == {
            "shard-0", "shard-1", "shard-2", "shard-3"
        }
        spans = {e["tid"] for e in evs
                 if e["ph"] == "X" and e["name"] == "round"}
        assert spans == {0, 1, 2, 3}  # every shard dispatched rounds

    def test_steal_arrows_present_and_paired(self):
        evs = self._doc()["traceEvents"]
        instants = [e for e in evs
                    if e.get("cat") == "steal" and e["ph"] == "i"]
        starts = {e["id"]: e for e in evs
                  if e.get("cat") == "steal" and e["ph"] == "s"}
        finishes = [e for e in evs
                    if e.get("cat") == "steal" and e["ph"] == "f"]
        assert instants  # the steal golden must show migrations
        assert len(starts) == len(finishes) == len(instants)
        for f in finishes:  # arrow crosses tracks: victim != thief
            assert f["tid"] != starts[f["id"]]["tid"]

    def test_round_spans_are_ordered_per_track(self):
        evs = self._doc()["traceEvents"]
        by_track: dict = {}
        for e in evs:
            if e["ph"] == "X" and e["name"] == "round":
                by_track.setdefault(e["tid"], []).append(e["ts"])
        for ts in by_track.values():
            assert ts == sorted(ts)  # virtual clock: monotone per shard


# ----------------------------------------------------------------- lazy import
def test_obs_never_imported_when_disabled():
    """The obs-off hot path must not even import repro.obs."""
    code = (
        "import sys\n"
        "import replay\n"
        "replay.SCENARIOS['sim_raw_fused']()\n"
        "bad = sorted(m for m in sys.modules if m.startswith('repro.obs'))\n"
        "assert not bad, bad\n"
        "print('CLEAN')\n"
    )
    env = dict(os.environ, PYTHONPATH=f"src{os.pathsep}tests")
    res = subprocess.run(
        [sys.executable, "-c", code],
        cwd=str(REPO), capture_output=True, text=True, env=env,
    )
    assert res.returncode == 0, res.stderr
    assert "CLEAN" in res.stdout


# ------------------------------------------------------------ daemon endpoints
def _adapters(n=6):
    return [
        AdapterSpec(
            a,
            nbytes=(a + 1) * 1_000_000,
            tenant="interactive" if a % 2 else "batch",
        )
        for a in range(n)
    ]


def _reqs(n=12):
    return [
        Request(
            request_id=i,
            adapter_id=(i * 5) % 6,
            arrival_time=0.01 * i,
            prompt_len=32 + (i % 7) * 16,
            max_new_tokens=32,
        )
        for i in range(n)
    ]


class TestDaemonEndpoints:
    def test_journal_admission_and_round_metrics(self, tmp_path):
        adm = AdmissionController({"batch": AdmissionQuota(max_queue_depth=2)})
        obs = Observability()
        eng = LifeRaftEngine(
            _adapters(), ServeConfig(adapter_slots=3, fuse_k=2, adaptive=True),
            obs=obs,
        )
        d = ServiceDaemon(ServingHost(eng), tmp_path / "j",
                          admission=adm, obs=obs)
        accepted = rejected = 0
        for r in _reqs():  # no pumping: the batch tenant must hit quota
            try:
                d.submit(r)
                accepted += 1
            except AdmissionRejected:
                rejected += 1
        assert rejected > 0
        d.pump()
        snap = d.metrics_snapshot()
        m = snap["metrics"]
        # Every synced submit ack paid an append AND an fsync barrier.
        appends = m["liferaft_journal_append_seconds"]["series"][0]
        fsyncs = m["liferaft_journal_fsync_seconds"]["series"][0]
        assert appends["count"] >= accepted + rejected
        assert fsyncs["count"] >= accepted + rejected
        assert fsyncs["sum"] > 0.0
        # Admission verdicts balance the submissions.
        verdicts = {
            (s["labels"]["tenant"], s["labels"]["verdict"]): s["value"]
            for s in m["liferaft_admission_total"]["series"]
        }
        assert sum(verdicts.values()) == accepted + rejected
        assert verdicts.get(("batch", "rejected"), 0) == rejected
        reasons = m["liferaft_admission_rejected_total"]["series"]
        assert {s["labels"]["reason"] for s in reasons} == {"queue_depth"}
        # The engine shared the same Observability: rounds were recorded.
        assert m["liferaft_rounds_total"]["series"][0]["value"] > 0
        # Text exposition serves the same registry.
        text = d.metrics_text()
        assert "# TYPE liferaft_admission_total counter" in text
        assert 'verdict="rejected"' in text
        assert "liferaft_journal_fsync_seconds_bucket" in text

    def test_obs_off_endpoints_are_empty(self, tmp_path):
        eng = LifeRaftEngine(
            _adapters(), ServeConfig(adapter_slots=3, fuse_k=2)
        )
        d = ServiceDaemon(ServingHost(eng), tmp_path / "j")
        assert d.metrics_text() == ""
        assert d.metrics_snapshot() == {}


# ------------------------------------------------------------- control explain
def test_control_explain_names_the_trigger_signal():
    obs, _ = _obs_run("serving_adaptive")
    events = obs.snapshot()["control_explain"]
    assert events  # the adaptive scenario moves the vector
    for e in events:
        assert {"track", "clock", "field", "from", "to", "message"} <= set(e)
        assert e["from"] != e["to"]
    fields = {e["field"] for e in events}
    assert "alpha" in fields
    # The message leads with the field's trigger signal (docs/adaptive.md).
    alpha_msgs = [e["message"] for e in events if e["field"] == "alpha"]
    assert any("saturation" in m for m in alpha_msgs)

"""Golden-trace replay fixture for DispatchLoop decision logs.

The scheduler's correctness story rests on decision *bit-identity*: the
incremental lazy-heap index must choose exactly the buckets the naive
O(B) oracle would, and refactors of the scheduling invariants (per-tenant
alpha, partial spill, resident-prefix accounting) must not silently move
a single decision on configurations whose behavior is meant to be
preserved.  This module provides the shared machinery:

* ``TraceRecorder`` — an ``on_round`` tap for ``DispatchLoop`` that
  serializes every scheduling round into a plain-data entry: decisions
  (bucket id, score, residency, queue size), the applied ControlVector,
  the round cost, and spill transitions.  Scores are float64 and survive
  JSON round-trips exactly (``repr`` shortest-round-trip), so a diff is a
  *bit* diff, not an approx one.
* ``diff_traces`` — structural diff of two decision logs; returns
  human-readable divergence records (empty list == bit-identical).
* ``save_trace`` / ``load_trace`` — versioned JSON golden files.
* Scenario builders (``sim_scenario``, ``serving_scenario``,
  ``crossmatch_scenario``) — fixed-seed single-tenant workloads replayed
  through the *real* DispatchLoop of the simulator, the serving engine,
  and the cross-match engine.  Golden files are produced by
  ``python -m tests.make_golden`` (run from the repo root) and asserted
  against in ``tests/test_replay_golden.py``.

Used by both the single-tenant regression suite (golden files recorded
before the multi-tenant refactor) and the per-tenant tests (goldens
recorded at feature introduction, guarding future drift).
"""
from __future__ import annotations

import pathlib

import numpy as np

# The entry codec was promoted to ``repro.core.journal`` (the write-ahead
# decision journal shares the golden-trace schema); the names below stay
# re-exported so existing imports keep working.
from repro.core.journal import (  # noqa: F401  (re-exports)
    TRACE_SCHEMA_VERSION,
    diff_entries as diff_traces,
    encode_outcome,
    encode_steal,
    format_entry as _fmt,
    load_trace,
    save_trace,
)

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


# --------------------------------------------------------------- recording
class TraceRecorder:
    """``DispatchLoop.on_round`` tap: appends one plain-data entry per
    scheduling round."""

    def __init__(self) -> None:
        self.entries: list[dict] = []

    def __call__(self, outcome) -> None:
        self.entries.append(encode_outcome(outcome))


class ShardTraceRecorder(TraceRecorder):
    """Recorder for the sharded coordinator: rounds interleave across
    shard-local DispatchLoops, so each entry carries its ``shard`` id, and
    steal migrations appear as their own in-order entries — the golden
    pins the interleaving AND the steal schedule, not just per-shard
    decisions."""

    def on_round(self, shard_id: int, outcome) -> None:
        self.entries.append(encode_outcome(outcome, shard=shard_id))

    def on_steal(self, ev) -> None:
        self.entries.append(encode_steal(ev))


# --------------------------------------------------------------- scenarios
def _identity_range(lo, hi):
    return np.arange(lo, hi + 1)


def sim_trace(seed: int, n: int = 140, buckets: int = 60, gap: float = 0.04,
              depth_hi: int = 14):
    """Deterministic mixed-depth query trace for the simulator scenarios."""
    from repro.core import Query

    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(n):
        t += float(rng.exponential(gap))
        b = int(rng.integers(0, buckets))
        ks = np.full(int(rng.integers(1, depth_hi)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    return qs


def two_tenant_trace(seed: int, horizon: float = 8.0, flood_gap: float = 0.05,
                     depth_lo: int = 40, depth_hi: int = 90,
                     interactive_gap: float = 0.4):
    """Interactive singletons + a deep batch flood, tenant-tagged (the
    paper-§6 starvation scenario, also used by bench_adaptive).  The
    defaults are frozen into the ``sim_two_tenant`` golden — harsher
    floods go through the keyword knobs."""
    from repro.core import Query

    rng = np.random.default_rng(seed)
    qs, qid, t = [], 0, 0.0
    while t < horizon:  # batch flood: deep queries on 8 hot buckets
        t += float(rng.exponential(flood_gap))
        b = int(rng.integers(0, 8))
        ks = np.full(int(rng.integers(depth_lo, depth_hi)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks, meta={"tenant": "batch"}))
        qid += 1
    t = 0.0
    while t < horizon:  # sparse interactive singletons on cold buckets
        t += float(rng.exponential(interactive_gap))
        b = int(rng.integers(8, 160))
        ks = np.full(int(rng.integers(1, 3)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks, meta={"tenant": "interactive"}))
        qid += 1
    return qs


def two_tenant_plane(budget_bytes=None):
    from repro.core import ControlConfig, TenantControlPlane, TenantPolicy

    return TenantControlPlane(
        [
            TenantPolicy(
                "interactive",
                ControlConfig(
                    alpha_init=0.9, alpha_min=0.7, alpha_max=1.0,
                    alpha_step=0.2, rate_knee=30.0, depth_knee=5_000.0,
                    fuse_k_max=2,
                ),
            ),
            TenantPolicy(
                "batch",
                ControlConfig(
                    alpha_init=0.2, alpha_min=0.0, alpha_max=0.4,
                    alpha_step=0.2, rate_knee=10.0, depth_knee=2_000.0,
                    fuse_k_max=6,
                ),
                weight=2.0,
            ),
        ],
        global_budget_bytes=budget_bytes,
        halflife_s=3.0,
    )


def sim_scenario(name: str, obs=None) -> list[dict]:
    """Simulator DispatchLoop scenarios (cost-model executor).

    ``obs`` threads a ``repro.obs.Observability`` through the harness —
    the obs-on bit-identity tests replay every golden with it attached."""
    from repro.core import (
        ControlConfig, ControlLoop, CostModel, LifeRaftScheduler,
        PrefetchConfig, simulate_batched, run_policy,
    )

    rec = TraceRecorder()
    if name == "sim_two_tenant":
        # Multi-tenant plane + byte-accurate partial spill: the golden was
        # recorded at feature introduction and guards against future drift
        # of the per-tenant scheduler invariants.
        # Flood heavy enough to saturate (object arrival > service rate)
        # and a tight budget, so the arbiter + partial spill actually
        # engage mid-flood — the golden must pin the sigma-scored path.
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.1, probe_bytes=16.0)
        simulate_batched(
            two_tenant_trace(41, flood_gap=0.015, depth_lo=80, depth_hi=150,
                             interactive_gap=0.12),
            _identity_range,
            LifeRaftScheduler(cost, 0.5, normalized=True), cost,
            cache_capacity=8, control=two_tenant_plane(budget_bytes=20_000.0),
            on_round=rec, obs=obs,
        )
    elif name == "sim_raw_fused":
        # Raw-scale scoring, static knobs, fused top-k selection.
        run_policy(
            "liferaft", sim_trace(11), _identity_range,
            CostModel(T_b=0.8, T_m=2e-4), alpha=0.25, cache_capacity=8,
            fuse_k=3, on_round=rec, obs=obs,
        )
    elif name == "sim_norm_ctl":
        # normalized=True + closed-loop alpha/fuse_k laws (no spill budget:
        # spill *policy* is allowed to evolve; scheduler invariants are not).
        ctl = ControlLoop(ControlConfig(
            alpha_init=0.5, alpha_step=0.2, halflife_s=3.0,
            rate_knee=6.0, depth_knee=500.0, fuse_k_max=4,
        ))
        run_policy(
            "liferaft", sim_trace(23, n=180, buckets=90, gap=0.02),
            _identity_range, CostModel(T_b=0.8, T_m=2e-4), alpha=0.5,
            cache_capacity=8, normalized=True, control=ctl, on_round=rec,
            obs=obs,
        )
    elif name == "sim_prefetch":
        # Scan-horizon prefetch ON (recorded at feature introduction):
        # deep queues make compute comparable to T_b so staging genuinely
        # overlaps; the ControlLoop sizes H (AIMD on stall), the §6 byte
        # budget engages mid-flood, and the spill victim walk runs PRICED
        # (price_spill_victims) — this golden pins the prefetch-on
        # decision trace, the per-round residual stalls, and the priced
        # victim order against future drift.
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)
        ctl = ControlLoop(ControlConfig(
            alpha_init=0.5, alpha_step=0.2, halflife_s=2.0,
            rate_knee=12.0, depth_knee=1_500.0, fuse_k_max=3,
            spill_budget_bytes=3_000.0, price_spill_victims=True,
            prefetch_horizon_init=2, prefetch_horizon_max=8,
        ))
        run_policy(
            "liferaft", sim_trace(59, n=220, buckets=48, gap=0.012, depth_hi=60),
            _identity_range, cost, alpha=0.5, cache_capacity=8,
            normalized=True, control=ctl, on_round=rec,
            prefetch=PrefetchConfig(horizon=4, depth=4), obs=obs,
        )
    elif name == "sim_spill_paged":
        # §6 byte budget on a saturating flood: spill engages mid-trace,
        # drains disengage it, and work pages back *paged* (oldest units
        # first, T_spill-priced grants, never over the budget).  Recorded
        # at feature introduction; pins the paged-unspill decisions.
        cost = CostModel(T_b=0.06, T_m=2e-4, T_spill=0.3, probe_bytes=8.0)
        ctl = ControlLoop(ControlConfig(
            alpha_init=0.5, alpha_step=0.2, halflife_s=2.0,
            rate_knee=12.0, depth_knee=1_200.0, fuse_k_max=4,
            spill_budget_bytes=5_000.0,
        ))
        run_policy(
            "liferaft", sim_trace(37, n=240, buckets=40, gap=0.012, depth_hi=28),
            _identity_range, cost, alpha=0.5, cache_capacity=8,
            normalized=True, control=ctl, on_round=rec, obs=obs,
        )
    elif name == "sim_sharedplan":
        # Shared query plans ON (recorded at feature introduction): the
        # executor reports per-round shared-batch occupancy, the AIMD
        # share_width law widens under saturation and narrows under
        # padding, and every round's applied width is pinned via the
        # conditional ``share_width`` trace key.
        ctl = ControlLoop(ControlConfig(
            alpha_init=0.5, alpha_step=0.2, halflife_s=3.0,
            rate_knee=6.0, depth_knee=500.0, fuse_k_max=4,
            share_width_init=2, share_width_max=8,
        ))
        run_policy(
            "liferaft", sim_trace(43, n=200, buckets=50, gap=0.02),
            _identity_range, CostModel(T_b=0.8, T_m=2e-4), alpha=0.5,
            cache_capacity=8, normalized=True, control=ctl, on_round=rec,
            shared_plan=True, share_width=2, obs=obs,
        )
    else:
        raise ValueError(name)
    return rec.entries


def shard_skew_trace(seed: int, n: int = 220, buckets: int = 48,
                     gap: float = 0.01, depth_hi: int = 40):
    """Skewed-depth trace for the steal scenarios: bucket popularity is
    quadratically biased toward the low end of the SFC range, so the
    shard owning that range floods while the rest drain — the imbalance
    work stealing exists to fix."""
    from repro.core import Query

    rng = np.random.default_rng(seed)
    qs, t = [], 0.0
    for qid in range(n):
        t += float(rng.exponential(gap))
        b = int(rng.integers(0, buckets)) ** 2 // buckets
        ks = np.full(int(rng.integers(1, depth_hi)), b, dtype=np.uint64)
        qs.append(Query(qid, t, ks, ks))
    return qs


def shard_scenario(name: str, obs=None) -> list[dict]:
    """Multi-shard coordinator scenarios (``simulate_sharded``): the
    golden pins the cross-shard round interleaving, every shard-local
    decision, and (for the steal scenario) the migration schedule."""
    from repro.core import (
        ControlConfig, ControlLoop, CostModel, LifeRaftScheduler,
        ShardControlPlane, StealConfig, simulate_sharded,
    )

    rec = ShardTraceRecorder()
    if name == "sim_shard4":
        # Four shards, per-shard closed loops, the global plane
        # waterfilling the §6 byte budget across shards: the steady
        # multi-shard configuration the bench gates.
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)
        simulate_sharded(
            sim_trace(67, n=200, buckets=64, gap=0.015, depth_hi=30),
            _identity_range, cost,
            scheduler_factory=lambda: LifeRaftScheduler(
                cost, 0.5, normalized=True
            ),
            n_shards=4, cache_capacity=8, fuse_k=2,
            control_factory=lambda: ControlLoop(ControlConfig(
                alpha_init=0.5, alpha_step=0.2, halflife_s=2.0,
                rate_knee=12.0, depth_knee=1_200.0, fuse_k_max=3,
                spill_budget_bytes=4_000.0,
            )),
            plane=ShardControlPlane(4, spill_budget_bytes=8_000.0),
            on_round=rec.on_round, obs=obs,
        )
    elif name == "sim_shard_steal":
        # Skewed load + work stealing: drained shards migrate the hot
        # shard's top buckets.  The golden must contain at least one
        # steal entry (asserted in tests/test_shard.py) or it guards
        # nothing.
        cost = CostModel(T_b=0.08, T_m=2e-4, T_spill=0.2, probe_bytes=8.0)
        simulate_sharded(
            shard_skew_trace(71), _identity_range, cost,
            scheduler_factory=lambda: LifeRaftScheduler(
                cost, 0.5, normalized=True
            ),
            n_shards=4, cache_capacity=8, fuse_k=2,
            steal=StealConfig(low_water_bytes=0.0),
            on_round=rec.on_round, on_steal=rec.on_steal, obs=obs,
        )
    else:
        raise ValueError(name)
    return rec.entries


def serving_scenario(name: str, obs=None) -> list[dict]:
    """Serving-engine DispatchLoop scenarios (virtual-clock decode)."""
    from repro.serving import AdapterSpec, LifeRaftEngine, Request, ServeConfig

    n_adapters = 8
    w = 1.0 / np.arange(1, n_adapters + 1) ** 1.5
    w /= w.sum()
    adapters = [AdapterSpec(i, 8 << 30) for i in range(n_adapters)]

    def trace(seed, n, rate, prompt_lo, prompt_hi, max_new):
        rng = np.random.default_rng(seed)
        t, reqs = 0.0, []
        for i in range(n):
            t += float(rng.exponential(1.0 / rate))
            reqs.append(
                Request(i, int(rng.choice(n_adapters, p=w)), t,
                        int(rng.integers(prompt_lo, prompt_hi)), max_new)
            )
        return reqs

    if name == "serving_static":
        reqs = trace(31, 160, 150.0, 8, 64, 16)
        cfg = ServeConfig(policy="liferaft", alpha=0.25, fuse_k=2)
    elif name == "serving_adaptive":
        # Closed loop, again without a spill budget (see sim_norm_ctl).
        reqs = trace(31, 160, 150.0, 8, 64, 16)
        cfg = ServeConfig(policy="liferaft", adaptive=True, fuse_k_max=4)
    elif name == "serving_prefetch":
        # Scan-horizon prefetch on the serving engine: adapter weights
        # stage into HBM slots ahead of their dispatch on the modeled
        # DMA channel (recorded at feature introduction; pins the
        # prefetch-on decisions + stalls for this engine).  Heavy 48 GiB
        # adapters make the stage time exceed a decode quantum, so the
        # golden pins at least one residual-stall round.
        adapters = [AdapterSpec(i, 48 << 30) for i in range(n_adapters)]
        reqs = trace(61, 200, 300.0, 16, 96, 32)
        cfg = ServeConfig(
            policy="liferaft", adaptive=True, fuse_k_max=4, max_batch=8,
            control_halflife_s=1.0, prefetch=True, prefetch_horizon=2,
            prefetch_horizon_max=6, prefetch_depth=4,
        )
    elif name == "serving_spill_paged":
        # §6 byte budget on the serving engine: a deep-decode flood spills
        # prompt state to host, servicing pages back only the decoded
        # batch (no wholesale retire), and disengaged rounds page in
        # T_spill-priced grants.  Recorded at feature introduction; pins
        # the paged protocol on this engine.
        reqs = trace(53, 220, 400.0, 16, 96, 48)
        cfg = ServeConfig(
            policy="liferaft", adaptive=True, fuse_k_max=4, max_batch=8,
            spill_budget_bytes=25_000.0, spill_penalty_s=0.05,
            kv_bytes_per_token=16.0, control_halflife_s=1.0,
            rate_knee=200.0, depth_knee=64.0,
        )
    else:
        raise ValueError(name)
    eng = LifeRaftEngine(adapters, cfg, obs=obs)
    rec = TraceRecorder()
    # Chain, don't assign: with obs= the engine already installed its tap
    # at construction, and direct assignment would clobber it.
    eng.loop.add_round_tap(rec)
    eng.run(reqs)
    return rec.entries


def crossmatch_scenario(name: str = "crossmatch_fused", obs=None) -> list[dict]:
    """Cross-match engine DispatchLoop scenario (real kernel executor; the
    decision log depends only on the cost model, so this also checks the
    engine's execute/complete plumbing stays decision-neutral)."""
    from repro.crossmatch import CrossMatchEngine, TraceConfig, make_catalog, make_trace

    catalog = make_catalog(
        n_objects=2_000, objects_per_bucket=100, htm_level=6, seed=17
    )
    trace = make_trace(
        catalog,
        TraceConfig(n_queries=14, arrival_rate=2.0, objects_median=40, seed=19),
    )
    if name == "crossmatch_fused":
        eng = CrossMatchEngine(
            catalog, match_radius_rad=4e-3, fuse_k=3, obs=obs
        )
    elif name == "crossmatch_sharedplan":
        # Shared-plan ON with heterogeneous per-query predicates: each
        # query carries its own radius + magnitude cut, so the off-path
        # would dispatch one kernel per predicate class while the shared
        # path folds them into width-2 masked batches.  The decision log
        # pins that the shared executor stays decision-neutral (same cost
        # model) while its device-dispatch accounting differs.
        rng = np.random.default_rng(5)
        for q in trace:
            q.meta["radius"] = float(rng.choice([2e-3, 4e-3, 8e-3]))
            q.meta["mag_cut"] = float(rng.choice([23.0, 24.0, 25.0]))
        eng = CrossMatchEngine(
            catalog, match_radius_rad=4e-3, fuse_k=2,
            shared_plan=True, share_width=2, obs=obs,
        )
    else:
        raise ValueError(name)
    rec = TraceRecorder()
    # Chain, don't assign (see serving_scenario).
    eng.loop.add_round_tap(rec)
    eng.run(trace)
    return rec.entries


# Every builder accepts ``obs=None`` — the obs-on golden tests call
# ``SCENARIOS[name](obs=Observability())`` and diff against the same file.
SCENARIOS = {
    "sim_raw_fused": lambda obs=None: sim_scenario("sim_raw_fused", obs),
    "sim_norm_ctl": lambda obs=None: sim_scenario("sim_norm_ctl", obs),
    "sim_two_tenant": lambda obs=None: sim_scenario("sim_two_tenant", obs),
    "sim_spill_paged": lambda obs=None: sim_scenario("sim_spill_paged", obs),
    "sim_prefetch": lambda obs=None: sim_scenario("sim_prefetch", obs),
    "sim_sharedplan": lambda obs=None: sim_scenario("sim_sharedplan", obs),
    "sim_shard4": lambda obs=None: shard_scenario("sim_shard4", obs),
    "sim_shard_steal": lambda obs=None: shard_scenario("sim_shard_steal", obs),
    "serving_static": lambda obs=None: serving_scenario("serving_static", obs),
    "serving_adaptive": lambda obs=None: serving_scenario(
        "serving_adaptive", obs
    ),
    "serving_spill_paged": lambda obs=None: serving_scenario(
        "serving_spill_paged", obs
    ),
    "serving_prefetch": lambda obs=None: serving_scenario(
        "serving_prefetch", obs
    ),
    "crossmatch_fused": lambda obs=None: crossmatch_scenario(
        "crossmatch_fused", obs
    ),
    "crossmatch_sharedplan": lambda obs=None: crossmatch_scenario(
        "crossmatch_sharedplan", obs
    ),
}

# Scenarios whose goldens predate the multi-tenant refactor: bit-identity
# here proves the refactor moved NO single-tenant decision.  The rest were
# recorded at feature introduction and guard future drift.
PRE_REFACTOR_SCENARIOS = (
    "sim_raw_fused",
    "sim_norm_ctl",
    "serving_static",
    "serving_adaptive",
    "crossmatch_fused",
)

"""Regenerate the golden decision-log traces in ``tests/golden/``.

    PYTHONPATH=src python tests/make_golden.py [scenario ...]

Only run this deliberately: committing a regenerated golden declares
"the new decision log is the correct one" and waives bit-identity with
the previous behavior for that scenario.  The regression tests in
``tests/test_replay_golden.py`` exist precisely to make that waiver an
explicit, reviewed act instead of an accident.
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
import replay  # noqa: E402


def main(argv: list[str]) -> None:
    names = argv or sorted(replay.SCENARIOS)
    unknown = [n for n in names if n not in replay.SCENARIOS]
    if unknown:
        raise SystemExit(f"unknown scenarios: {unknown}; have {sorted(replay.SCENARIOS)}")
    replay.GOLDEN_DIR.mkdir(exist_ok=True)
    for name in names:
        entries = replay.SCENARIOS[name]()
        path = replay.GOLDEN_DIR / f"{name}.json"
        replay.save_trace(path, entries, meta={"scenario": name})
        print(f"  {name}: {len(entries)} rounds -> {path}")


if __name__ == "__main__":
    main(sys.argv[1:])
